//! The influence dataset D_i: (ALSH-features, influence-source labels)
//! pairs collected from the GS (paper Algorithm 2), plus batch assembly
//! for the `aip_update` / `aip_eval` artifacts and the training loop.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::nn::NetState;
use crate::runtime::ArtifactSet;
use crate::util::npk::Tensor;
use crate::util::rng::Pcg64;

/// One episode's worth of (feature, label) rows, kept contiguous so the
/// recurrent AIP can train on in-episode windows.
#[derive(Clone, Debug, Default)]
struct Episode {
    feats: Vec<f32>,  // [len × feat_dim]
    labels: Vec<f32>, // [len × n_heads]
    len: usize,
}

/// Agent i's dataset D_i.
///
/// Episodes live in a `VecDeque` so capacity eviction pops the oldest
/// episode in O(1); the old `Vec` + `remove(0)` shifted every surviving
/// episode per eviction — quadratic churn on the hot collection path once
/// a dataset reached capacity.
#[derive(Clone, Debug)]
pub struct InfluenceDataset {
    feat_dim: usize,
    n_heads: usize,
    episodes: VecDeque<Episode>,
    total_rows: usize,
    /// Rows to keep (oldest episodes evicted beyond this).
    capacity_rows: usize,
}

impl InfluenceDataset {
    pub fn new(feat_dim: usize, n_heads: usize, capacity_rows: usize) -> Self {
        InfluenceDataset {
            feat_dim,
            n_heads,
            episodes: VecDeque::new(),
            total_rows: 0,
            capacity_rows,
        }
    }

    /// An unbounded staging dataset: rows accumulate (in the async-collect
    /// slot, off-thread) without ever evicting, and `append_from` replays
    /// them into the real dataset — with its real capacity — at the drain
    /// point.
    pub fn staging(feat_dim: usize, n_heads: usize) -> Self {
        InfluenceDataset::new(feat_dim, n_heads, usize::MAX)
    }

    /// [`staging`](Self::staging) with this dataset's row shape.
    pub fn staging_like(&self) -> Self {
        Self::staging(self.feat_dim, self.n_heads)
    }

    pub fn len(&self) -> usize {
        self.total_rows
    }

    pub fn is_empty(&self) -> bool {
        self.total_rows == 0
    }

    pub fn clear(&mut self) {
        self.episodes.clear();
        self.total_rows = 0;
    }

    pub fn begin_episode(&mut self) {
        self.episodes.push_back(Episode::default());
    }

    pub fn push(&mut self, feat: &[f32], label: &[f32]) {
        debug_assert_eq!(feat.len(), self.feat_dim);
        debug_assert_eq!(label.len(), self.n_heads);
        if self.episodes.is_empty() {
            self.begin_episode();
        }
        let ep = self.episodes.back_mut().unwrap();
        ep.feats.extend_from_slice(feat);
        ep.labels.extend_from_slice(label);
        ep.len += 1;
        self.total_rows += 1;
        self.evict_over_capacity();
    }

    /// Evict the oldest full episodes beyond capacity. The newest episode
    /// is never evicted, even when it alone exceeds the capacity.
    fn evict_over_capacity(&mut self) {
        while self.total_rows > self.capacity_rows && self.episodes.len() > 1 {
            let old = self.episodes.pop_front().expect("len > 1");
            self.total_rows -= old.len;
        }
    }

    /// Merge every episode of `staged` into `self`, in collection order,
    /// draining `staged` (it is left empty, ready for reuse as a staging
    /// buffer). The final state is bit-identical to having pushed the
    /// staged rows directly: each episode is appended whole and then the
    /// same oldest-episode eviction runs — eviction is monotone front
    /// removal driven by the running total, so batching it per episode
    /// cannot change which episodes survive.
    pub fn append_from(&mut self, staged: &mut InfluenceDataset) {
        debug_assert_eq!(staged.feat_dim, self.feat_dim);
        debug_assert_eq!(staged.n_heads, self.n_heads);
        for ep in staged.episodes.drain(..) {
            self.total_rows += ep.len;
            self.episodes.push_back(ep);
            self.evict_over_capacity();
        }
        staged.total_rows = 0;
    }

    /// Order-sensitive FNV-1a digest of the full dataset content (episode
    /// structure + f32 bit patterns). Two datasets with equal fingerprints
    /// hold byte-identical rows in the same episode layout — the
    /// determinism contract the collection tests pin.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        eat(self.feat_dim as u64);
        eat(self.n_heads as u64);
        eat(self.episodes.len() as u64);
        for ep in &self.episodes {
            eat(ep.len as u64);
            for &f in ep.feats.iter().chain(ep.labels.iter()) {
                eat(f.to_bits() as u64);
            }
        }
        h
    }

    /// Assemble a flat minibatch for the FNN AIP update:
    /// feats [B, F], labels [B, H].
    pub fn sample_flat(&self, batch: usize, rng: &mut Pcg64) -> Option<(Tensor, Tensor)> {
        if self.total_rows == 0 {
            return None;
        }
        let mut feats = Tensor::zeros(&[batch, self.feat_dim]);
        let mut labels = Tensor::zeros(&[batch, self.n_heads]);
        for b in 0..batch {
            let (ep, t) = self.random_row(rng);
            feats.data[b * self.feat_dim..(b + 1) * self.feat_dim]
                .copy_from_slice(&ep.feats[t * self.feat_dim..(t + 1) * self.feat_dim]);
            labels.data[b * self.n_heads..(b + 1) * self.n_heads]
                .copy_from_slice(&ep.labels[t * self.n_heads..(t + 1) * self.n_heads]);
        }
        Some((feats, labels))
    }

    /// Assemble a windowed minibatch for the GRU AIP update:
    /// feats [B, T, F], labels [B, T, H]. Windows are contiguous in-episode
    /// spans starting from a random offset (truncated BPTT with h0 = 0;
    /// the update artifact unrolls exactly `seq` steps).
    ///
    /// Each of the dataset's `len - seq + 1` windows is equally likely:
    /// one draw over the window total, walked through the episodes. The
    /// old two-draw scheme (uniform episode, then uniform offset)
    /// over-weighted windows from short episodes — an episode with 2
    /// windows was sampled as often as one with 200.
    pub fn sample_windows(
        &self,
        batch: usize,
        seq: usize,
        rng: &mut Pcg64,
    ) -> Option<(Tensor, Tensor)> {
        debug_assert!(seq > 0);
        let mut total_windows = 0u64;
        let mut eligible: Vec<(&Episode, u64)> = Vec::new();
        for e in self.episodes.iter().filter(|e| e.len >= seq) {
            let w = (e.len - seq + 1) as u64;
            total_windows += w;
            eligible.push((e, w));
        }
        if eligible.is_empty() {
            return None;
        }
        let mut feats = Tensor::zeros(&[batch, seq, self.feat_dim]);
        let mut labels = Tensor::zeros(&[batch, seq, self.n_heads]);
        for b in 0..batch {
            let mut w = rng.below(total_windows);
            let mut it = eligible.iter();
            let (ep, start) = loop {
                let (ep, wins) = it.next().expect("window index within total");
                if w < *wins {
                    break (*ep, w as usize);
                }
                w -= wins;
            };
            for t in 0..seq {
                let src = start + t;
                let fdst = (b * seq + t) * self.feat_dim;
                feats.data[fdst..fdst + self.feat_dim]
                    .copy_from_slice(&ep.feats[src * self.feat_dim..(src + 1) * self.feat_dim]);
                let ldst = (b * seq + t) * self.n_heads;
                labels.data[ldst..ldst + self.n_heads]
                    .copy_from_slice(&ep.labels[src * self.n_heads..(src + 1) * self.n_heads]);
            }
        }
        Some((feats, labels))
    }

    /// Whether a training batch can be assembled for `spec`'s AIP — the
    /// RNG-free twin of the samplers' `None` condition. Sampling
    /// None-ness is content-only (flat: empty dataset; recurrent: no
    /// episode holding a full `aip_seq` window) and the dataset is
    /// immutable during a retrain, so per agent an update run performs
    /// either all of its epochs or zero — the all-or-zero property the
    /// fused retrain's eligibility gate relies on.
    pub fn can_sample(&self, recurrent: bool, seq: usize) -> bool {
        if recurrent {
            self.episodes.iter().any(|e| e.len >= seq)
        } else {
            self.total_rows > 0
        }
    }

    fn random_row(&self, rng: &mut Pcg64) -> (&Episode, usize) {
        let mut idx = rng.below(self.total_rows as u64) as usize;
        for ep in &self.episodes {
            if idx < ep.len {
                return (ep, idx);
            }
            idx -= ep.len;
        }
        unreachable!("row index out of range")
    }

    /// Train the AIP for `epochs` gradient steps on this dataset (paper
    /// §3.2: supervised cross-entropy on (l, u) pairs). Mutates `net`.
    /// Returns the mean CE over the performed steps.
    ///
    /// §Perf: params/m/v stay device-resident and chain across epochs;
    /// only the sampled batches and the scalar CE cross the host boundary.
    pub fn train(
        &self,
        arts: &ArtifactSet,
        net: &mut NetState,
        epochs: usize,
        rng: &mut Pcg64,
    ) -> Result<f32> {
        ensure!(!self.is_empty(), "cannot train AIP on an empty dataset");
        let spec = &arts.spec;
        let engine = &arts.engine;
        let mut steps = 0usize;
        // packed [flat|m|v|ce] state chained across gradient steps
        let p = net.flat.len();
        let mut packed = Vec::with_capacity(3 * p + 1);
        packed.extend_from_slice(&net.flat.data);
        packed.extend_from_slice(&net.m.data);
        packed.extend_from_slice(&net.v.data);
        packed.push(0.0);
        let mut d_state = engine.upload(&Tensor::new(vec![3 * p + 1], packed))?;
        for _ in 0..epochs {
            let batch = if spec.aip_recurrent {
                self.sample_windows(spec.aip_batch, spec.aip_seq, rng)
            } else {
                self.sample_flat(spec.aip_batch, rng)
            };
            let Some((feats, labels)) = batch else {
                break; // not enough data for a full window batch
            };
            net.step += 1;
            // single packed upload: [t | feats | labels]
            let mut b = Vec::with_capacity(1 + feats.len() + labels.len());
            b.push(net.step as f32);
            b.extend_from_slice(&feats.data);
            b.extend_from_slice(&labels.data);
            let d_batch = engine.upload(&Tensor::new(vec![b.len()], b))?;
            let mut outs = arts.aip_update.run_b(&[&d_state, &d_batch])?;
            d_state = outs.pop().unwrap();
            steps += 1;
        }
        if steps == 0 {
            return Ok(f32::NAN);
        }
        let out = d_state.to_tensor()?.data;
        net.absorb(
            Tensor::new(vec![p], out[..p].to_vec()),
            Tensor::new(vec![p], out[p..2 * p].to_vec()),
            Tensor::new(vec![p], out[2 * p..3 * p].to_vec()),
        );
        // tail = CE of the LAST gradient step
        Ok(out[3 * p])
    }

    /// Evaluate the AIP's CE loss on a batch drawn from this dataset
    /// (Fig. 4 right: CE of the AIPs on fresh GS trajectories).
    pub fn evaluate(
        &self,
        arts: &ArtifactSet,
        net: &NetState,
        rng: &mut Pcg64,
    ) -> Result<Option<f32>> {
        let spec = &arts.spec;
        let batch = if spec.aip_recurrent {
            self.sample_windows(spec.aip_batch, spec.aip_seq, rng)
        } else {
            self.sample_flat(spec.aip_batch, rng)
        };
        let Some((feats, labels)) = batch else {
            return Ok(None);
        };
        let outs = arts.aip_eval.run(&[net.flat.clone(), feats, labels])?;
        Ok(Some(outs[0].data[0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_dataset(n_eps: usize, ep_len: usize) -> InfluenceDataset {
        let mut d = InfluenceDataset::new(3, 2, 10_000);
        for e in 0..n_eps {
            d.begin_episode();
            for t in 0..ep_len {
                let f = [e as f32, t as f32, 0.5];
                let l = [(t % 2) as f32, ((t + e) % 2) as f32];
                d.push(&f, &l);
            }
        }
        d
    }

    #[test]
    fn rows_counted_across_episodes() {
        let d = make_dataset(3, 5);
        assert_eq!(d.len(), 15);
    }

    #[test]
    fn flat_sampling_has_right_shapes() {
        let d = make_dataset(2, 4);
        let mut rng = Pcg64::seed(0);
        let (f, l) = d.sample_flat(6, &mut rng).unwrap();
        assert_eq!(f.dims, vec![6, 3]);
        assert_eq!(l.dims, vec![6, 2]);
        // every sampled row must exist in the dataset (feat[2] == 0.5)
        for b in 0..6 {
            assert_eq!(f.data[b * 3 + 2], 0.5);
        }
    }

    #[test]
    fn window_sampling_is_contiguous() {
        let d = make_dataset(1, 10);
        let mut rng = Pcg64::seed(1);
        let (f, _l) = d.sample_windows(4, 3, &mut rng).unwrap();
        assert_eq!(f.dims, vec![4, 3, 3]);
        for b in 0..4 {
            // feat[1] is the within-episode time index: must increase by 1
            let t0 = f.data[(b * 3) * 3 + 1];
            let t1 = f.data[(b * 3 + 1) * 3 + 1];
            let t2 = f.data[(b * 3 + 2) * 3 + 1];
            assert_eq!(t1 - t0, 1.0);
            assert_eq!(t2 - t1, 1.0);
        }
    }

    #[test]
    fn windows_need_long_enough_episodes() {
        let d = make_dataset(2, 3);
        let mut rng = Pcg64::seed(2);
        assert!(d.sample_windows(2, 5, &mut rng).is_none());
        assert!(d.sample_windows(2, 3, &mut rng).is_some());
    }

    #[test]
    fn empty_dataset_yields_none() {
        let d = InfluenceDataset::new(3, 2, 100);
        let mut rng = Pcg64::seed(3);
        assert!(d.sample_flat(2, &mut rng).is_none());
        assert!(d.sample_windows(2, 2, &mut rng).is_none());
    }

    #[test]
    fn capacity_evicts_oldest_episodes() {
        let mut d = InfluenceDataset::new(1, 1, 10);
        for e in 0..5 {
            d.begin_episode();
            for _ in 0..4 {
                d.push(&[e as f32], &[0.0]);
            }
        }
        assert!(d.len() <= 10 + 4, "len={} should hover near capacity", d.len());
        // the oldest episode (e=0) must be gone
        let mut rng = Pcg64::seed(4);
        for _ in 0..50 {
            let (f, _) = d.sample_flat(1, &mut rng).unwrap();
            assert!(f.data[0] > 0.5, "evicted episode still sampled");
        }
    }

    #[test]
    fn push_without_begin_opens_episode() {
        let mut d = InfluenceDataset::new(1, 1, 100);
        d.push(&[1.0], &[1.0]);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn eviction_removes_episodes_in_age_order() {
        // capacity 6, episodes of 3 rows tagged by their index: after five
        // episodes only the two newest (tags 3, 4) survive.
        let mut d = InfluenceDataset::new(1, 1, 6);
        for e in 0..5 {
            d.begin_episode();
            for _ in 0..3 {
                d.push(&[e as f32], &[0.0]);
            }
        }
        assert_eq!(d.len(), 6);
        let mut rng = Pcg64::seed(7);
        for _ in 0..100 {
            let (f, _) = d.sample_flat(1, &mut rng).unwrap();
            assert!(f.data[0] >= 3.0, "evicted episode {} still sampled", f.data[0]);
        }
    }

    #[test]
    fn single_over_capacity_episode_is_kept() {
        // One episode larger than the whole capacity: the newest episode
        // is never evicted, so the dataset holds all of it.
        let mut d = InfluenceDataset::new(1, 1, 10);
        d.begin_episode();
        for t in 0..15 {
            d.push(&[t as f32], &[1.0]);
        }
        assert_eq!(d.len(), 15, "growing episode must survive its own overflow");
        // The next episode's rows evict the oversized one as usual.
        d.begin_episode();
        d.push(&[99.0], &[1.0]);
        assert_eq!(d.len(), 1);
        let mut rng = Pcg64::seed(8);
        let (f, _) = d.sample_flat(1, &mut rng).unwrap();
        assert_eq!(f.data[0], 99.0);
    }

    #[test]
    fn append_from_matches_direct_pushes_including_eviction() {
        // Reference: rows pushed straight into a capacity-bounded dataset.
        // Candidate: same rows collected into an unbounded staging dataset,
        // merged via append_from. Final contents must be bit-identical.
        let rows: &[(usize, usize)] = &[(0, 4), (1, 7), (2, 3), (3, 9), (4, 2)];
        let mut direct = InfluenceDataset::new(2, 1, 12);
        // pre-existing content the merge must evict exactly like pushes do
        direct.begin_episode();
        for t in 0..5 {
            direct.push(&[-1.0, t as f32], &[0.5]);
        }
        let mut merged = direct.clone();
        let mut staging = merged.staging_like();
        assert_eq!(staging.len(), 0);
        for &(e, n) in rows {
            direct.begin_episode();
            staging.begin_episode();
            for t in 0..n {
                let f = [e as f32, t as f32];
                let l = [(e + t) as f32];
                direct.push(&f, &l);
                staging.push(&f, &l);
            }
        }
        merged.append_from(&mut staging);
        assert!(staging.is_empty(), "append_from must drain the staging dataset");
        assert_eq!(merged.len(), direct.len());
        assert_eq!(merged.fingerprint(), direct.fingerprint());
    }

    #[test]
    fn window_sampling_is_proportional_to_window_count() {
        // Episode A: 3 rows -> 1 window of seq 3; episode B: 12 rows ->
        // 10 windows. A must be drawn ~1/11 of the time, not ~1/2.
        let mut d = InfluenceDataset::new(1, 1, 10_000);
        d.begin_episode();
        for _ in 0..3 {
            d.push(&[0.0], &[0.0]); // episode A marker: feat 0
        }
        d.begin_episode();
        for _ in 0..12 {
            d.push(&[1.0], &[0.0]); // episode B marker: feat 1
        }
        let mut rng = Pcg64::seed(11);
        let draws = 20_000usize;
        let mut from_a = 0usize;
        for _ in 0..draws {
            let (f, _) = d.sample_windows(1, 3, &mut rng).unwrap();
            if f.data[0] == 0.0 {
                from_a += 1;
            }
        }
        let frac = from_a as f64 / draws as f64;
        let want = 1.0 / 11.0;
        assert!(
            (frac - want).abs() < 0.02,
            "episode A drawn {frac:.3} of the time, want ~{want:.3}"
        );
    }

    #[test]
    fn fingerprint_distinguishes_content_and_structure() {
        let a = make_dataset(2, 4);
        let b = make_dataset(2, 4);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // different row content
        let mut c = make_dataset(2, 4);
        c.push(&[9.0, 9.0, 9.0], &[1.0, 1.0]);
        assert_ne!(a.fingerprint(), c.fingerprint());
        // same rows, different episode structure
        let mut flat = InfluenceDataset::new(3, 2, 10_000);
        flat.begin_episode();
        for e in 0..2 {
            for t in 0..4 {
                flat.push(&[e as f32, t as f32, 0.5], &[(t % 2) as f32, ((t + e) % 2) as f32]);
            }
        }
        assert_eq!(flat.len(), a.len());
        assert_ne!(a.fingerprint(), flat.fingerprint());
    }
}
