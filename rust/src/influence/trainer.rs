//! Fused [N]-wide AIP retraining: every epoch is ONE `aip_update_b` call
//! over a [`TrainBank`]'s `[N, 3P+1]` state stack, bit-identical to N
//! sequential [`InfluenceDataset::train`] calls in agent order (the
//! equivalence is pinned in `tests/native_retrain.rs`).

use anyhow::{bail, ensure, Result};

use crate::nn::NetState;
use crate::runtime::{ArtifactSet, DeviceTensor, TrainBank};
use crate::util::npk::Tensor;
use crate::util::rng::Pcg64;

use super::InfluenceDataset;

/// One agent's inputs to [`train_aip_fused`]: its mutable AIP net (step
/// counter + absorbed result), its dataset (immutable for the duration of
/// the retrain), and its own RNG (batch-sampling stream — consumed
/// exactly like the sequential per-agent path).
pub struct FusedAipAgent<'a> {
    pub net: &'a mut NetState,
    pub dataset: &'a InfluenceDataset,
    pub rng: &'a mut Pcg64,
}

/// Retrain ALL N agents' AIPs as one fused chain: exactly `epochs`
/// `aip_update_b` calls, independent of N, each consuming an
/// `[N, batch_len]` staging tensor against the bank's `[N, 3P+1]` state
/// stack. Returns the per-agent CE of the LAST gradient step — the same
/// scalar [`InfluenceDataset::train`] reports — and `NAN` (with no
/// absorption) at `epochs = 0`, also like the sequential path.
///
/// Bit-identical to calling [`InfluenceDataset::train`] once per agent in
/// agent order: the batched artifact runs the identical per-agent update
/// row, each agent samples its `epochs` batches from its OWN RNG (agent
/// i's stream is consumed only by agent i's draws, in epoch order — the
/// epoch-major interleaving cannot reorder a single agent's draws), and
/// engine calls consume no RNG.
///
/// Callers must gate on [`InfluenceDataset::can_sample`] for every agent:
/// per agent a retrain performs either all of its epochs or zero (the
/// samplers' `None` condition is content-only and the dataset is
/// immutable here), so a mixed set must take the sequential fallback to
/// preserve the ineligible agents' NAN / no-absorb semantics.
pub fn train_aip_fused(
    arts: &ArtifactSet,
    agents: &mut [FusedAipAgent<'_>],
    epochs: usize,
) -> Result<Vec<f32>> {
    ensure!(!agents.is_empty(), "no agents to retrain");
    let n = agents.len();
    let spec = &arts.spec;
    let p = spec.aip_params;
    let recurrent = spec.aip_recurrent;
    let seq = if recurrent { spec.aip_seq } else { 1 };
    for (i, a) in agents.iter().enumerate() {
        ensure!(
            a.net.flat.len() == p,
            "agent {i}: AIP net has {} params, artifact set trains {p}",
            a.net.flat.len()
        );
        ensure!(!a.dataset.is_empty(), "agent {i}: cannot train AIP on an empty dataset");
        ensure!(
            a.dataset.can_sample(recurrent, seq),
            "agent {i}: dataset cannot assemble a full AIP batch — gate the fused \
             path on InfluenceDataset::can_sample and fall back to per-agent training"
        );
    }
    // Sequential parity at epochs = 0: no gradient step, no absorption,
    // CE reported as NAN.
    if epochs == 0 {
        return Ok(vec![f32::NAN; n]);
    }
    ensure!(
        arts.supports_fused_aip_update(n),
        "artifact set does not support the fused AIP update at N={n} — \
         re-run `make artifacts` (or use the per-agent retrain path)"
    );
    let exec = arts.aip_update_batched()?;
    let engine = &arts.engine;

    // Stack all agents' [flat|m|v|ce] rows device-side.
    let mut bank = TrainBank::with_tail(n, p, 1);
    for (i, a) in agents.iter().enumerate() {
        bank.stage(i, a.net)?;
    }

    // Single packed staging tensor per epoch, one row per agent:
    // [t | feats | labels], re-staged into one reused device slot.
    let batch_len = 1 + spec.aip_batch * seq * (spec.aip_feat + spec.aip_heads);
    let mut t_batch = Tensor::zeros(&[n, batch_len]);
    let mut d_batch: Option<DeviceTensor> = None;
    for _epoch in 0..epochs {
        for (i, a) in agents.iter_mut().enumerate() {
            let batch = if recurrent {
                a.dataset.sample_windows(spec.aip_batch, spec.aip_seq, a.rng)
            } else {
                a.dataset.sample_flat(spec.aip_batch, a.rng)
            };
            let Some((feats, labels)) = batch else {
                bail!(
                    "agent {i}: dataset stopped sampling mid-retrain (can_sample is \
                     content-only and the dataset is immutable here — this is a bug)"
                );
            };
            let base = i * batch_len;
            a.net.step += 1;
            t_batch.data[base] = a.net.step as f32;
            t_batch.data[base + 1..base + 1 + feats.len()].copy_from_slice(&feats.data);
            t_batch.data[base + 1 + feats.len()..base + batch_len]
                .copy_from_slice(&labels.data);
        }
        engine.upload_to(&t_batch, &mut d_batch)?;
        let d_state = bank.state(engine)?;
        exec.run_inout(d_state, d_batch.as_ref().expect("staged"))?;
    }

    // ONE download for all agents, then per-agent absorption (tail = that
    // agent's last-step CE).
    bank.download_into_staged()?;
    let mut ces = Vec::with_capacity(n);
    for (i, a) in agents.iter_mut().enumerate() {
        let row = bank.staged_row(i);
        let flat = Tensor::new(vec![p], row[..p].to_vec());
        let m = Tensor::new(vec![p], row[p..2 * p].to_vec());
        let v = Tensor::new(vec![p], row[2 * p..3 * p].to_vec());
        a.net.absorb(flat, m, v);
        bank.mark_absorbed(i, a.net.version);
        ces.push(row[3 * p]);
    }
    Ok(ces)
}
