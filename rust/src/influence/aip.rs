//! The AIP runtime: streaming forward calls into the `aip_forward`
//! artifact plus influence-source sampling for the local simulators.
//!
//! Like the policy runtime, the AIP keeps its parameter vector
//! device-resident across forwards (§Perf), and the hot path is buffer-out:
//! `forward_into` writes the head probabilities into a caller-owned slice
//! and `sample_u_into` writes the sampled influence realisation into the
//! caller's `u` scratch, so the steady-state IALS step loop performs no
//! host heap allocation. The allocating `forward`/`sample_u` wrappers stay
//! for tests and one-shot callers.

use anyhow::Result;

use crate::nn::NetState;
use crate::runtime::{ArtifactSet, DeviceTensor, NetSpec};
use crate::util::npk::Tensor;
use crate::util::rng::Pcg64;

/// One agent's AIP: network state + the streaming hidden state used while
/// driving its IALS (paper Algorithm 3, line `u ~ I(·|l)`).
pub struct AipRuntime {
    pub net: NetState,
    /// GRU hidden state across the current episode (width `aip_hstate`).
    hstate: Vec<f32>,
    /// Staging tensors reused for every upload ([1, feat] / [1, h]).
    in_feat: Tensor,
    in_h: Tensor,
    dev_params: Option<(u64, DeviceTensor)>,
    n_heads: usize,
    n_cls: usize,
    feat_dim: usize,
    h_dim: usize,
}

impl AipRuntime {
    pub fn new(spec: &NetSpec, net: NetState) -> Self {
        AipRuntime {
            net,
            hstate: vec![0.0; spec.aip_hstate],
            in_feat: Tensor::zeros(&[1, spec.aip_feat]),
            in_h: Tensor::zeros(&[1, spec.aip_hstate]),
            dev_params: None,
            n_heads: spec.aip_heads,
            n_cls: spec.aip_cls,
            feat_dim: spec.aip_feat,
            h_dim: spec.aip_hstate,
        }
    }

    /// Width of the probability vector `forward_into` produces.
    pub fn u_dim(&self) -> usize {
        self.n_heads * self.n_cls.max(1)
    }

    /// Number of influence heads = width of the sampled `u`.
    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// Reset the episode memory (call at episode boundaries).
    pub fn reset_episode(&mut self) {
        self.hstate.fill(0.0);
    }

    fn params(&mut self, arts: &ArtifactSet) -> Result<&DeviceTensor> {
        let stale = match &self.dev_params {
            Some((v, _)) => *v != self.net.version,
            None => true,
        };
        if stale {
            let buf = arts.engine.upload(&self.net.flat)?;
            self.dev_params = Some((self.net.version, buf));
        }
        Ok(&self.dev_params.as_ref().unwrap().1)
    }

    /// Predict influence-source probabilities for the current ALSH step
    /// into `probs_out` (len = `u_dim()`), advancing the hidden state.
    pub fn forward_into(
        &mut self,
        arts: &ArtifactSet,
        feat: &[f32],
        probs_out: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(feat.len(), self.feat_dim);
        let u_dim = self.u_dim();
        debug_assert_eq!(probs_out.len(), u_dim);
        self.in_feat.data.copy_from_slice(feat);
        self.in_h.data.copy_from_slice(&self.hstate);
        let feat_t = arts.engine.upload(&self.in_feat)?;
        let h_t = arts.engine.upload(&self.in_h)?;
        let p = self.params(arts)?;
        let outs = arts.aip_forward.run_b(&[p, &feat_t, &h_t])?;
        // packed output: [probs(U) | h'(H)]
        let packed = outs[0].to_tensor()?.data;
        debug_assert_eq!(packed.len(), u_dim + self.h_dim);
        probs_out.copy_from_slice(&packed[..u_dim]);
        self.hstate.copy_from_slice(&packed[u_dim..]);
        Ok(())
    }

    /// Allocating wrapper around `forward_into` (tests / one-shot calls).
    pub fn forward(&mut self, arts: &ArtifactSet, feat: &[f32]) -> Result<Vec<f32>> {
        let mut probs = vec![0.0; self.u_dim()];
        self.forward_into(arts, feat, &mut probs)?;
        Ok(probs)
    }

    /// Sample an influence realisation `u` into `u_out` (len = `n_heads`),
    /// in the local simulator's input format: Bernoulli heads → {0,1} per
    /// head; categorical heads → class index per head.
    pub fn sample_u_into(&self, probs: &[f32], rng: &mut Pcg64, u_out: &mut [f32]) {
        debug_assert_eq!(u_out.len(), self.n_heads);
        if self.n_cls <= 1 {
            for (o, &p) in u_out.iter_mut().zip(probs.iter().take(self.n_heads)) {
                *o = if rng.bernoulli(p as f64) { 1.0 } else { 0.0 };
            }
        } else {
            for (h, o) in u_out.iter_mut().enumerate() {
                let group = &probs[h * self.n_cls..(h + 1) * self.n_cls];
                *o = rng.categorical(group) as f32;
            }
        }
    }

    /// Allocating wrapper around `sample_u_into` (tests / one-shot calls).
    pub fn sample_u(&self, probs: &[f32], rng: &mut Pcg64) -> Vec<f32> {
        let mut u = vec![0.0; self.n_heads];
        self.sample_u_into(probs, rng, &mut u);
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_spec(cls: usize) -> NetSpec {
        NetSpec {
            domain: "t".into(),
            obs_dim: 4,
            act_dim: 2,
            policy_recurrent: false,
            policy_hstate: 1,
            policy_params: 10,
            aip_feat: 6,
            aip_recurrent: cls > 1,
            aip_hstate: 3,
            aip_params: 10,
            aip_heads: 4,
            aip_cls: cls,
            u_dim: 4 * cls.max(1),
            minibatch: 4,
            aip_batch: 4,
            aip_seq: 2,
        }
    }

    fn runtime(cls: usize) -> AipRuntime {
        let spec = dummy_spec(cls);
        let net = NetState::new(&Tensor::zeros(&[spec.aip_params]));
        AipRuntime::new(&spec, net)
    }

    #[test]
    fn bernoulli_sampling_tracks_probs() {
        let rt = runtime(1);
        let mut rng = Pcg64::seed(0);
        let probs = [1.0f32, 0.0, 1.0, 0.0];
        for _ in 0..20 {
            assert_eq!(rt.sample_u(&probs, &mut rng), vec![1.0, 0.0, 1.0, 0.0]);
        }
    }

    #[test]
    fn categorical_sampling_picks_valid_classes() {
        let rt = runtime(4);
        let mut rng = Pcg64::seed(1);
        // head h always class h
        let mut probs = vec![0.0f32; 16];
        for h in 0..4 {
            probs[h * 4 + h] = 1.0;
        }
        assert_eq!(rt.sample_u(&probs, &mut rng), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn sample_u_into_matches_allocating_form() {
        let rt = runtime(1);
        let probs = [1.0f32, 0.0, 1.0, 1.0];
        let mut rng_a = Pcg64::seed(5);
        let mut rng_b = Pcg64::seed(5);
        let owned = rt.sample_u(&probs, &mut rng_a);
        let mut buf = [9.0f32; 4];
        rt.sample_u_into(&probs, &mut rng_b, &mut buf);
        assert_eq!(owned.as_slice(), &buf);
    }

    #[test]
    fn u_dim_accounts_for_classes() {
        assert_eq!(runtime(1).u_dim(), 4);
        assert_eq!(runtime(4).u_dim(), 16);
        assert_eq!(runtime(4).n_heads(), 4);
    }

    #[test]
    fn reset_zeroes_hidden_state() {
        let mut rt = runtime(4);
        rt.hstate.fill(0.7);
        rt.reset_episode();
        assert!(rt.hstate.iter().all(|&x| x == 0.0));
    }
}
