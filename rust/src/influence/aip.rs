//! The AIP runtime: streaming forward calls into the `aip_forward`
//! artifact plus influence-source sampling for the local simulators.
//!
//! Since the batch-first redesign this is a thin view over a single-row
//! [`AipBank`] (`runtime::batch`): the bank keeps the parameter row
//! device-resident across forwards (re-uploaded only on
//! `NetState::version` bumps) and owns the staging tensors and the GRU
//! hidden state, so one forward implementation serves both the B=1 IALS
//! step loop here and the batched joint GS collection phase. The hot path
//! is buffer-out (`forward_into` / `sample_u_into`); the steady-state
//! step loop performs no host heap allocation.

use anyhow::Result;

use crate::nn::NetState;
use crate::runtime::{AipBank, ArtifactSet, NetSpec};
use crate::util::rng::Pcg64;

/// One agent's AIP: network state + the streaming hidden state used while
/// driving its IALS (paper Algorithm 3, line `u ~ I(·|l)`).
pub struct AipRuntime {
    pub net: NetState,
    bank: AipBank,
}

impl AipRuntime {
    pub fn new(spec: &NetSpec, net: NetState) -> Self {
        AipRuntime { net, bank: AipBank::new(spec, 1, false) }
    }

    /// Width of the probability vector `forward_into` produces.
    pub fn u_dim(&self) -> usize {
        self.bank.u_dim()
    }

    /// Number of influence heads = width of the sampled `u`.
    pub fn n_heads(&self) -> usize {
        self.bank.n_heads()
    }

    /// Reset the episode memory (call at episode boundaries).
    pub fn reset_episode(&mut self) {
        self.bank.reset_episodes();
    }

    /// Predict influence-source probabilities for the current ALSH step
    /// into `probs_out` (len = `u_dim()`), advancing the hidden state.
    pub fn forward_into(
        &mut self,
        arts: &ArtifactSet,
        feat: &[f32],
        probs_out: &mut [f32],
    ) -> Result<()> {
        self.bank.stage(&arts.engine, 0, &self.net)?;
        self.bank.forward_into(arts, feat, probs_out)
    }

    /// Allocating wrapper around `forward_into` (tests / one-shot calls).
    #[cfg(test)]
    pub fn forward(&mut self, arts: &ArtifactSet, feat: &[f32]) -> Result<Vec<f32>> {
        let mut probs = vec![0.0; self.u_dim()];
        self.forward_into(arts, feat, &mut probs)?;
        Ok(probs)
    }

    /// Sample an influence realisation `u` into `u_out` (len = `n_heads`),
    /// in the local simulator's input format: Bernoulli heads → {0,1} per
    /// head; categorical heads → class index per head.
    pub fn sample_u_into(&self, probs: &[f32], rng: &mut Pcg64, u_out: &mut [f32]) {
        self.bank.sample_u_into(probs, rng, u_out);
    }

    /// Allocating wrapper around `sample_u_into` (tests only).
    #[cfg(test)]
    pub fn sample_u(&self, probs: &[f32], rng: &mut Pcg64) -> Vec<f32> {
        let mut u = vec![0.0; self.n_heads()];
        self.sample_u_into(probs, rng, &mut u);
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::npk::Tensor;

    fn dummy_spec(cls: usize) -> NetSpec {
        NetSpec {
            domain: "t".into(),
            obs_dim: 4,
            act_dim: 2,
            policy_recurrent: false,
            policy_hstate: 1,
            policy_params: 10,
            aip_feat: 6,
            aip_recurrent: cls > 1,
            aip_hstate: 3,
            aip_params: 10,
            aip_heads: 4,
            aip_cls: cls,
            u_dim: 4 * cls.max(1),
            minibatch: 4,
            aip_batch: 4,
            aip_seq: 2,
            policy_h1: 0,
            policy_h2: 0,
            aip_hid: 0,
            batch_n: 0,
            batch_replicas: 1,
            ppo: crate::runtime::layout::PpoHypers::default(),
            aip: crate::runtime::layout::AipHypers::default(),
        }
    }

    fn runtime(cls: usize) -> AipRuntime {
        let spec = dummy_spec(cls);
        let net = NetState::new(&Tensor::zeros(&[spec.aip_params]));
        AipRuntime::new(&spec, net)
    }

    #[test]
    fn bernoulli_sampling_tracks_probs() {
        let rt = runtime(1);
        let mut rng = Pcg64::seed(0);
        let probs = [1.0f32, 0.0, 1.0, 0.0];
        for _ in 0..20 {
            assert_eq!(rt.sample_u(&probs, &mut rng), vec![1.0, 0.0, 1.0, 0.0]);
        }
    }

    #[test]
    fn categorical_sampling_picks_valid_classes() {
        let rt = runtime(4);
        let mut rng = Pcg64::seed(1);
        // head h always class h
        let mut probs = vec![0.0f32; 16];
        for h in 0..4 {
            probs[h * 4 + h] = 1.0;
        }
        assert_eq!(rt.sample_u(&probs, &mut rng), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn sample_u_into_matches_allocating_form() {
        let rt = runtime(1);
        let probs = [1.0f32, 0.0, 1.0, 1.0];
        let mut rng_a = Pcg64::seed(5);
        let mut rng_b = Pcg64::seed(5);
        let owned = rt.sample_u(&probs, &mut rng_a);
        let mut buf = [9.0f32; 4];
        rt.sample_u_into(&probs, &mut rng_b, &mut buf);
        assert_eq!(owned.as_slice(), &buf);
    }

    #[test]
    fn u_dim_accounts_for_classes() {
        assert_eq!(runtime(1).u_dim(), 4);
        assert_eq!(runtime(4).u_dim(), 16);
        assert_eq!(runtime(4).n_heads(), 4);
    }
}
