//! Shard transports: how [`Frame`]s move between the coordinator and a
//! shard worker (DESIGN.md §15).
//!
//! [`ShardTransport`] is deliberately tiny — blocking `send`/`recv` of one
//! frame — because the coordinator enforces its per-shard step deadlines
//! *outside* the transport, via `DeferredHandle::wait_until` on a deferred
//! receive job. Two implementations:
//!
//! * [`ChannelTransport`] — an in-process loopback over `std::sync::mpsc`
//!   that still carries ENCODED frames, so the single-process reference
//!   path exercises the exact same wire bytes as the socket path.
//! * [`SocketTransport`] — length-prefixed frames over TCP or a Unix
//!   domain socket (an address containing `/` is a filesystem path). A
//!   read timeout bounds how long a recv can hang on a dead-but-connected
//!   peer; a clean EOF surfaces as `Err`, never a zero-length frame.
//!
//! Every transport error is terminal for that shard: the coordinator
//! marks the shard disconnected and permanently re-executes its range on
//! the local pool (`dist::DistPlan`), so a lost worker degrades throughput
//! but never correctness.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::wire::Frame;

/// Hard ceiling on one frame's body size — a corrupt length prefix errors
/// here instead of asking the allocator for gigabytes.
const MAX_FRAME_BYTES: usize = 256 << 20;

/// Blocking, frame-oriented, point-to-point transport to one peer.
pub trait ShardTransport {
    fn send(&mut self, frame: &Frame) -> Result<()>;
    fn recv(&mut self) -> Result<Frame>;
}

// ---------------------------------------------------------------------
// In-process loopback.
// ---------------------------------------------------------------------

/// mpsc-backed loopback carrying encoded frame bodies. The reference
/// transport: no sockets, no timeouts, but the full wire codec on every
/// message.
pub struct ChannelTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl ChannelTransport {
    /// A connected pair: what one side sends, the other receives.
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (tx_a, rx_b) = channel();
        let (tx_b, rx_a) = channel();
        (ChannelTransport { tx: tx_a, rx: rx_a }, ChannelTransport { tx: tx_b, rx: rx_b })
    }
}

impl ShardTransport for ChannelTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let mut body = Vec::new();
        frame.encode(&mut body);
        self.tx.send(body).map_err(|_| anyhow!("shard channel closed"))
    }

    fn recv(&mut self) -> Result<Frame> {
        let body = self.rx.recv().map_err(|_| anyhow!("shard channel closed"))?;
        Frame::decode(&body)
    }
}

// ---------------------------------------------------------------------
// Socket transport (TCP / Unix domain).
// ---------------------------------------------------------------------

enum SocketStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl SocketStream {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            SocketStream::Tcp(s) => s.set_read_timeout(d),
            SocketStream::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.read(buf),
            SocketStream::Unix(s) => s.read(buf),
        }
    }

    fn write_all_bytes(&mut self, buf: &[u8]) -> std::io::Result<()> {
        match self {
            SocketStream::Tcp(s) => s.write_all(buf),
            SocketStream::Unix(s) => s.write_all(buf),
        }
    }
}

/// Length-prefixed frames (`u32` body length, then the body) over a
/// stream socket. Incoming bytes accumulate in an internal buffer until a
/// complete frame is available, so a frame split across arbitrarily many
/// reads — or a read that returns mid-frame — reassembles correctly.
pub struct SocketTransport {
    stream: SocketStream,
    /// Bytes received but not yet consumed as a complete frame.
    rx: Vec<u8>,
    /// Reusable send scratch: length prefix + encoded body.
    tx: Vec<u8>,
}

impl SocketTransport {
    fn new(stream: SocketStream, read_timeout: Option<Duration>) -> Result<SocketTransport> {
        stream.set_read_timeout(read_timeout).context("set socket read timeout")?;
        if let SocketStream::Tcp(s) = &stream {
            // One frame per step in each direction: latency matters more
            // than batching.
            let _ = s.set_nodelay(true);
        }
        Ok(SocketTransport { stream, rx: Vec::new(), tx: Vec::new() })
    }

    /// Wrap an accepted/connected TCP stream.
    pub fn from_tcp(stream: TcpStream, read_timeout: Option<Duration>) -> Result<SocketTransport> {
        Self::new(SocketStream::Tcp(stream), read_timeout)
    }

    /// Wrap an accepted/connected Unix-domain stream.
    pub fn from_unix(
        stream: UnixStream,
        read_timeout: Option<Duration>,
    ) -> Result<SocketTransport> {
        Self::new(SocketStream::Unix(stream), read_timeout)
    }

    /// Connect to `addr`: a string containing `/` is a Unix-socket path,
    /// anything else a TCP `host:port`.
    pub fn connect(addr: &str, read_timeout: Option<Duration>) -> Result<SocketTransport> {
        if addr.contains('/') {
            let s = UnixStream::connect(addr)
                .with_context(|| format!("connect unix socket {addr}"))?;
            Self::from_unix(s, read_timeout)
        } else {
            let s = TcpStream::connect(addr).with_context(|| format!("connect tcp {addr}"))?;
            Self::from_tcp(s, read_timeout)
        }
    }

    /// Connect with exponential backoff — the shard-worker side, which
    /// typically races the coordinator's `bind`.
    pub fn connect_with_backoff(
        addr: &str,
        attempts: usize,
        first_delay: Duration,
        read_timeout: Option<Duration>,
    ) -> Result<SocketTransport> {
        let attempts = attempts.max(1);
        let mut delay = first_delay;
        let mut last_err = None;
        for k in 0..attempts {
            match Self::connect(addr, read_timeout) {
                Ok(t) => return Ok(t),
                Err(e) => last_err = Some(e),
            }
            if k + 1 < attempts {
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_secs(2));
            }
        }
        Err(last_err.unwrap().context(format!("connect {addr} after {attempts} attempts")))
    }

    /// A complete frame body if the rx buffer holds one.
    fn try_extract(&mut self) -> Result<Option<Vec<u8>>> {
        if self.rx.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.rx[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME_BYTES {
            bail!("frame length prefix {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
        }
        if self.rx.len() < 4 + len {
            return Ok(None);
        }
        let body = self.rx[4..4 + len].to_vec();
        self.rx.drain(..4 + len);
        Ok(Some(body))
    }
}

impl ShardTransport for SocketTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.tx.clear();
        self.tx.extend_from_slice(&[0; 4]);
        frame.encode(&mut self.tx);
        let len = (self.tx.len() - 4) as u32;
        self.tx[..4].copy_from_slice(&len.to_le_bytes());
        self.stream.write_all_bytes(&self.tx).context("send frame")
    }

    fn recv(&mut self) -> Result<Frame> {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            if let Some(body) = self.try_extract()? {
                return Frame::decode(&body);
            }
            match self.stream.read_some(&mut chunk) {
                Ok(0) => bail!("peer closed the connection"),
                Ok(n) => self.rx.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    bail!("read timed out waiting for a frame");
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("recv frame"),
            }
        }
    }
}

/// Listening side of the socket transport (the coordinator).
pub enum ShardListener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl ShardListener {
    /// Bind `addr` (same `/`-means-Unix convention as
    /// [`SocketTransport::connect`]). A stale Unix socket file from a
    /// previous run is removed first.
    pub fn bind(addr: &str) -> Result<ShardListener> {
        if addr.contains('/') {
            let _ = std::fs::remove_file(addr);
            Ok(ShardListener::Unix(
                UnixListener::bind(addr).with_context(|| format!("bind unix socket {addr}"))?,
            ))
        } else {
            Ok(ShardListener::Tcp(
                TcpListener::bind(addr).with_context(|| format!("bind tcp {addr}"))?,
            ))
        }
    }

    /// The bound TCP port (tests bind port 0 and need the real one).
    pub fn local_port(&self) -> Option<u16> {
        match self {
            ShardListener::Tcp(l) => l.local_addr().ok().map(|a| a.port()),
            ShardListener::Unix(_) => None,
        }
    }

    /// Accept one worker connection.
    pub fn accept(&self, read_timeout: Option<Duration>) -> Result<SocketTransport> {
        match self {
            ShardListener::Tcp(l) => {
                let (s, _) = l.accept().context("accept shard worker")?;
                SocketTransport::from_tcp(s, read_timeout)
            }
            ShardListener::Unix(l) => {
                let (s, _) = l.accept().context("accept shard worker")?;
                SocketTransport::from_unix(s, read_timeout)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::BoundaryEvent;

    fn sample() -> Frame {
        Frame::Step {
            step_id: 3,
            actions: vec![1, 0],
            sync: vec![(BoundaryEvent::TrafficInflow { agent: 0, lane: 2 }, true)],
        }
    }

    #[test]
    fn channel_pair_roundtrips_frames() {
        let (mut a, mut b) = ChannelTransport::pair();
        a.send(&sample()).unwrap();
        assert_eq!(b.recv().unwrap(), sample());
        b.send(&Frame::Shutdown).unwrap();
        assert_eq!(a.recv().unwrap(), Frame::Shutdown);
    }

    #[test]
    fn channel_recv_errors_after_peer_drop() {
        let (mut a, b) = ChannelTransport::pair();
        drop(b);
        assert!(a.send(&Frame::Shutdown).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn tcp_transport_roundtrips_and_reassembles() {
        let listener = ShardListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_port().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = SocketTransport::connect_with_backoff(
                &format!("127.0.0.1:{port}"),
                20,
                Duration::from_millis(5),
                Some(Duration::from_secs(10)),
            )
            .unwrap();
            t.send(&sample()).unwrap();
            t.send(&Frame::Hello { version: 9 }).unwrap();
            // Echo what the server sends back.
            let f = t.recv().unwrap();
            t.send(&f).unwrap();
        });
        let mut server = listener.accept(Some(Duration::from_secs(10))).unwrap();
        // Two frames may land in one read; the buffer must split them.
        assert_eq!(server.recv().unwrap(), sample());
        assert_eq!(server.recv().unwrap(), Frame::Hello { version: 9 });
        let big = Frame::StepRes {
            step_id: 1,
            events: Vec::new(),
            state: vec![7u8; 200_000], // forces multi-read reassembly
            rngs: vec![(1, 2); 16],
        };
        server.send(&big).unwrap();
        assert_eq!(server.recv().unwrap(), big);
        client.join().unwrap();
    }

    #[test]
    fn tcp_recv_times_out_then_errors_on_eof() {
        let listener = ShardListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_port().unwrap();
        let client = std::thread::spawn(move || {
            let t = SocketTransport::connect(
                &format!("127.0.0.1:{port}"),
                Some(Duration::from_secs(10)),
            )
            .unwrap();
            // Send nothing for a while, then hang up.
            std::thread::sleep(Duration::from_millis(80));
            drop(t);
        });
        let mut server = listener.accept(Some(Duration::from_millis(20))).unwrap();
        let err = server.recv().unwrap_err();
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
        client.join().unwrap();
        // After the peer hangs up, recv reports the closed connection.
        std::thread::sleep(Duration::from_millis(100));
        assert!(server.recv().is_err());
    }

    #[test]
    fn unix_socket_transport_roundtrips() {
        let dir = std::env::temp_dir().join(format!("dials-ut-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard.sock");
        let addr = path.to_str().unwrap().to_string();
        let listener = ShardListener::bind(&addr).unwrap();
        let addr2 = addr.clone();
        let client = std::thread::spawn(move || {
            let mut t = SocketTransport::connect_with_backoff(
                &addr2,
                20,
                Duration::from_millis(5),
                None,
            )
            .unwrap();
            t.send(&Frame::Hello { version: 1 }).unwrap();
            assert_eq!(t.recv().unwrap(), Frame::Shutdown);
        });
        let mut server = listener.accept(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(server.recv().unwrap(), Frame::Hello { version: 1 });
        server.send(&Frame::Shutdown).unwrap();
        client.join().unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn connect_backoff_reports_the_address_after_exhaustion() {
        // Nothing listens on this port (bound then dropped to reserve it
        // briefly; races are harmless — the error path only needs SOME
        // refused/failed connect).
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = SocketTransport::connect_with_backoff(
            &format!("127.0.0.1:{port}"),
            2,
            Duration::from_millis(1),
            None,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("after 2 attempts"), "{err:#}");
    }
}
