//! The distributed sharded-stepping driver (DESIGN.md §15).
//!
//! [`DistPlan`] is the multi-process sibling of `sim::ShardPlan`: the
//! same scatter/merge decomposition of the joint GS transition, with the
//! scatter phase running in `P` shard-worker PROCESSES behind a
//! [`ShardTransport`] instead of on pool threads. The coordinator keeps
//! the authoritative full-GS mirror (always post-merge) plus its own copy
//! of every agent's PCG64 stream, which is what makes the two safety nets
//! below possible.
//!
//! **One-hop sync scoping (DARL1N-style).** After the deterministic
//! `key()`-ordered merge, each resolved `(event, applied)` pair is shipped
//! only to the shards owning one of the event's consumers
//! (`BoundaryEvent::consumers`) — never broadcast. Shard adjacency derived
//! from the domain topology (`PartitionedGs::neighbours`) double-checks
//! the scoping in debug builds: consumers of one event always lie in
//! adjacent shards.
//!
//! **Straggler speculation.** Every shard gets a step deadline from an
//! EWMA of its observed step wall times (or `DIALS_DIST_DEADLINE_MS`).
//! A shard that misses it has its range re-executed speculatively by the
//! local pool, using the coordinator's stream copies and pre-step mirror
//! state — bit-identical to what the worker is still computing, because
//! `step_local` is deterministic given (state, actions, streams). The
//! plan COMMITS to the speculation: the worker's late reply is drained
//! and discarded at the next step, so there is never a race between an
//! import and a speculative write. A shard whose transport errors is
//! marked disconnected and speculated every step from then on — a lost
//! worker degrades throughput, never correctness
//! (`tests/dist_equivalence.rs`, `tests/dist_transport.rs`).

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Domain;
use crate::exec::{DeferredHandle, WorkerPool};
use crate::sim::{
    partition_ranges, BoundaryEvent, GlobalSim, PartitionedGs, ShardRange, ShardSlots,
};
use crate::util::rng::Pcg64;
use crate::util::timer::Ewma;

use super::transport::{ChannelTransport, ShardListener, ShardTransport};
use super::wire::{Frame, WIRE_VERSION};
use super::worker::StraggleInjection;

/// Read timeout on coordinator-side sockets: bounds how long a drain of a
/// dead-but-connected peer can hang before it degrades to a disconnect.
const COORD_READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Smoothing of the per-shard step-time EWMA.
const EWMA_ALPHA: f64 = 0.3;
/// Deadline = max(this floor, EWMA * DEADLINE_MULT): generous enough that
/// scheduler noise does not trigger speculation storms.
const DEADLINE_FLOOR: Duration = Duration::from_millis(250);
const DEADLINE_MULT: f64 = 4.0;
/// Before the first observed sample there is no EWMA; allow a cold
/// worker (artifact mmap, allocator warmup) plenty of time.
const FIRST_STEP_DEADLINE: Duration = Duration::from_secs(30);

type SharedTransport = Arc<Mutex<Box<dyn ShardTransport + Send>>>;

/// Per-shard speculation scratch. `events` doubles as the per-step event
/// stash for EVERY shard: an in-time worker reply parks its events here,
/// a speculative re-execution writes its own — either way the merge
/// gathers from one place, in shard order.
struct SpecScratch {
    range: ShardRange,
    /// Re-execute this range locally this step (straggler/disconnect).
    active: bool,
    events: Vec<BoundaryEvent>,
    rewards: Vec<f32>,
}

/// Multi-process sharded GS stepping, bit-identical to the in-process
/// `--gs-shards` path at any process count.
pub struct DistPlan {
    ranges: Vec<ShardRange>,
    /// Agent -> owning shard.
    owner: Vec<usize>,
    /// Shard x shard one-hop adjacency (self-inclusive), from the domain
    /// topology. Debug-checks the sync scoping.
    adjacent: Vec<Vec<bool>>,
    transports: Vec<SharedTransport>,
    /// Outstanding receive of a shard that missed its deadline; drained
    /// (and discarded) before that shard's next send.
    pending: Vec<Option<DeferredHandle<Frame>>>,
    disconnected: Vec<bool>,
    ewma: Vec<Ewma>,
    deadline_override: Option<Duration>,
    /// Coordinator copies of ALL agent streams (speculation + import).
    rngs: ShardSlots<Pcg64>,
    spec: Vec<SpecScratch>,
    merged: Vec<BoundaryEvent>,
    outcomes: Vec<bool>,
    /// Next step's per-shard resolved-event sync, built by the merge.
    sync_next: Vec<Vec<(BoundaryEvent, bool)>>,
    step_id: u64,
    speculations: u64,
    n_agents: usize,
    /// Loopback worker threads (empty for socket transports).
    workers: Vec<JoinHandle<Result<()>>>,
}

impl DistPlan {
    /// Spawn `procs` in-process worker threads over [`ChannelTransport`]
    /// loopback — same protocol, same wire bytes, no sockets. The
    /// reference distributed path (benches, equivalence tests).
    pub fn loopback(
        procs: usize,
        domain: Domain,
        grid_side: usize,
        gs: &mut dyn GlobalSim,
    ) -> Result<DistPlan> {
        Self::loopback_straggle(procs, domain, grid_side, gs, None)
    }

    /// [`DistPlan::loopback`] with an artificial per-worker straggle
    /// injection (tests/benches of the speculation path).
    pub fn loopback_straggle(
        procs: usize,
        domain: Domain,
        grid_side: usize,
        gs: &mut dyn GlobalSim,
        straggle: Option<StraggleInjection>,
    ) -> Result<DistPlan> {
        let procs = procs.clamp(1, gs.n_agents());
        let mut transports: Vec<Box<dyn ShardTransport + Send>> = Vec::with_capacity(procs);
        let mut workers = Vec::with_capacity(procs);
        for k in 0..procs {
            let (coord, worker) = ChannelTransport::pair();
            transports.push(Box::new(coord));
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dials-shard-{k}"))
                    .spawn(move || {
                        let mut t = worker;
                        super::worker::serve(&mut t, straggle)
                    })
                    .context("spawn loopback shard worker")?,
            );
        }
        let mut plan = Self::from_transports(transports, domain, grid_side, gs)?;
        plan.workers = workers;
        Ok(plan)
    }

    /// Bind `addr` and accept `procs` shard-worker connections (the
    /// `--shard-addr` path; workers are separate `dials shard-worker`
    /// processes). Accept order assigns shard ranges.
    pub fn listen(
        addr: &str,
        procs: usize,
        domain: Domain,
        grid_side: usize,
        gs: &mut dyn GlobalSim,
    ) -> Result<DistPlan> {
        let listener = ShardListener::bind(addr)?;
        eprintln!("[dist] waiting for {procs} shard worker(s) on {addr}");
        let mut transports: Vec<Box<dyn ShardTransport + Send>> = Vec::with_capacity(procs);
        for _ in 0..procs {
            transports.push(Box::new(listener.accept(Some(COORD_READ_TIMEOUT))?));
        }
        Self::from_transports(transports, domain, grid_side, gs)
    }

    /// Build a plan over already-connected transports, performing the
    /// `Hello`/`Init` handshake with each worker in order.
    pub fn from_transports(
        transports: Vec<Box<dyn ShardTransport + Send>>,
        domain: Domain,
        grid_side: usize,
        gs: &mut dyn GlobalSim,
    ) -> Result<DistPlan> {
        let n = gs.n_agents();
        if grid_side * grid_side != n {
            bail!("grid side {grid_side} does not square to {n} agents");
        }
        let procs = transports.len();
        if procs == 0 {
            bail!("a distributed plan needs at least one shard transport");
        }
        let ranges = partition_ranges(n, procs);
        if ranges.len() != procs {
            bail!("more shard workers ({procs}) than agents ({n})");
        }
        let part = gs.as_partitioned().ok_or_else(|| {
            anyhow!("this global simulator does not implement the sharded stepping protocol")
        })?;

        let mut owner = vec![0usize; n];
        for (s, r) in ranges.iter().enumerate() {
            for a in r.start..r.end {
                owner[a] = s;
            }
        }
        // Shard adjacency from the domain topology: two shards are
        // adjacent iff they own one-hop-neighbouring agents.
        let mut adjacent = vec![vec![false; procs]; procs];
        let mut nb = Vec::new();
        for a in 0..n {
            adjacent[owner[a]][owner[a]] = true;
            nb.clear();
            part.neighbours(a, &mut nb);
            for &b in &nb {
                adjacent[owner[a]][owner[b]] = true;
                adjacent[owner[b]][owner[a]] = true;
            }
        }

        let mut shared = Vec::with_capacity(procs);
        for (s, mut t) in transports.into_iter().enumerate() {
            match t.recv().with_context(|| format!("handshake with shard {s}"))? {
                Frame::Hello { version } if version == WIRE_VERSION => {}
                Frame::Hello { version } => bail!(
                    "shard {s} speaks wire version {version}, this coordinator speaks {WIRE_VERSION}"
                ),
                other => bail!("expected Hello from shard {s}, got {}", other.name()),
            }
            t.send(&Frame::Init {
                domain,
                grid_side,
                start: ranges[s].start,
                end: ranges[s].end,
                n_agents: n,
            })
            .with_context(|| format!("init shard {s}"))?;
            shared.push(Arc::new(Mutex::new(t)));
        }

        let deadline_override = std::env::var("DIALS_DIST_DEADLINE_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis);
        let spec = ranges
            .iter()
            .map(|&range| SpecScratch {
                range,
                active: false,
                events: Vec::new(),
                rewards: vec![0.0; range.len()],
            })
            .collect();
        Ok(DistPlan {
            owner,
            adjacent,
            transports: shared,
            pending: (0..procs).map(|_| None).collect(),
            disconnected: vec![false; procs],
            ewma: (0..procs).map(|_| Ewma::new(EWMA_ALPHA)).collect(),
            deadline_override,
            rngs: ShardSlots::new(vec![Pcg64::new(0, 0); n]),
            spec,
            merged: Vec::new(),
            outcomes: Vec::new(),
            sync_next: vec![Vec::new(); procs],
            step_id: 0,
            speculations: 0,
            n_agents: n,
            ranges,
            workers: Vec::new(),
        })
    }

    pub fn n_procs(&self) -> usize {
        self.ranges.len()
    }

    /// Speculative local re-executions so far (straggler timeouts plus
    /// every step of a disconnected shard). Lands in the RunLog.
    pub fn speculations(&self) -> u64 {
        self.speculations
    }

    /// Shards currently marked disconnected.
    pub fn n_disconnected(&self) -> usize {
        self.disconnected.iter().filter(|&&d| d).count()
    }

    /// Fixed per-step deadline override (tests/benches force the
    /// speculation path with a tiny one; `DIALS_DIST_DEADLINE_MS` is the
    /// process-wide equivalent).
    pub fn set_deadline_override(&mut self, d: Duration) {
        self.deadline_override = Some(d);
    }

    fn deadline(&self, s: usize) -> Duration {
        if let Some(d) = self.deadline_override {
            return d;
        }
        match self.ewma[s].value() {
            Some(v) => DEADLINE_FLOOR.max(Duration::from_secs_f64(v * DEADLINE_MULT)),
            None => FIRST_STEP_DEADLINE,
        }
    }

    fn mark_disconnected(&mut self, s: usize) {
        if !self.disconnected[s] {
            self.disconnected[s] = true;
            let r = self.ranges[s];
            eprintln!(
                "[dist] shard {s} disconnected; agents [{}, {}) now run on the local pool",
                r.start, r.end
            );
        }
    }

    /// Replay an episode reset on every connected worker. `raw` is the
    /// episode RNG captured BEFORE `GlobalSim::reset` ran on the
    /// coordinator; `rng` is that same RNG AFTER the reset, from which
    /// the per-agent streams are re-derived in global order — the exact
    /// `ShardPlan::reseed` accounting, so dist and in-process runs share
    /// every stream. Transport failures degrade to disconnects, never
    /// errors: the mirror is always able to run the whole system.
    pub fn reseed(&mut self, raw: (u128, u128), rng: &mut Pcg64) {
        for s in 0..self.ranges.len() {
            if let Some(h) = self.pending[s].take() {
                // A late reply from the previous episode: drain, discard.
                if h.wait().is_err() {
                    self.mark_disconnected(s);
                }
            }
        }
        self.step_id = 0;
        for v in self.sync_next.iter_mut() {
            v.clear();
        }
        for s in 0..self.ranges.len() {
            if self.disconnected[s] {
                continue;
            }
            let ok = self.transports[s]
                .lock()
                .unwrap()
                .send(&Frame::Reset { state: raw.0, inc: raw.1 })
                .is_ok();
            if !ok {
                self.mark_disconnected(s);
            }
        }
        for (k, slot) in self.rngs.as_mut_slice().iter_mut().enumerate() {
            *slot = rng.split(k as u64 + 1);
        }
    }

    /// One distributed joint transition.
    pub fn step(
        &mut self,
        gs: &mut dyn GlobalSim,
        pool: &WorkerPool,
        actions: &[usize],
        rewards: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(actions.len(), self.n_agents);
        debug_assert_eq!(rewards.len(), self.n_agents);
        let part = gs.as_partitioned().ok_or_else(|| {
            anyhow!("this global simulator does not implement the sharded stepping protocol")
        })?;
        let procs = self.ranges.len();
        let step_id = self.step_id;
        self.step_id += 1;
        // With no helper threads the deferred lane never runs; fall back
        // to blocking receives (no straggler mitigation on 1 thread).
        let can_defer = pool.threads() > 1;
        let t0 = Instant::now();

        // -- Phase A: drain stale replies, ship Step frames, post recvs.
        let mut handles: Vec<Option<DeferredHandle<Frame>>> = (0..procs).map(|_| None).collect();
        for s in 0..procs {
            self.spec[s].active = false;
            if self.disconnected[s] {
                self.spec[s].active = true;
                continue;
            }
            if let Some(h) = self.pending[s].take() {
                // The late reply of a speculated step. The speculation
                // already committed, so the payload is discarded whatever
                // it says; only a transport error matters.
                if h.wait().is_err() {
                    self.mark_disconnected(s);
                    self.spec[s].active = true;
                    continue;
                }
            }
            let r = self.ranges[s];
            let frame = Frame::Step {
                step_id,
                actions: actions[r.start..r.end].iter().map(|&a| a as u32).collect(),
                sync: std::mem::take(&mut self.sync_next[s]),
            };
            let sent = self.transports[s].lock().unwrap().send(&frame).is_ok();
            if !sent {
                self.mark_disconnected(s);
                self.spec[s].active = true;
                continue;
            }
            let tr = Arc::clone(&self.transports[s]);
            handles[s] = Some(pool.submit_deferred(move || tr.lock().unwrap().recv()));
        }

        // -- Phase A2: collect replies within each shard's deadline.
        for s in 0..procs {
            let Some(mut handle) = handles[s].take() else { continue };
            let deadline = t0 + self.deadline(s);
            loop {
                let res = if can_defer {
                    match handle.wait_until(deadline) {
                        Some(r) => r,
                        None => {
                            // Straggler: park the receive, speculate.
                            self.pending[s] = Some(handle);
                            self.spec[s].active = true;
                            break;
                        }
                    }
                } else {
                    handle.wait()
                };
                match res {
                    Ok(Frame::StepRes { step_id: sid, events, state, rngs })
                        if sid == step_id =>
                    {
                        self.ewma[s].observe(t0.elapsed().as_secs_f64());
                        if let Err(e) = self.import_step_res(part, s, events, &state, &rngs) {
                            eprintln!("[dist] shard {s} sent a bad StepRes: {e:#}");
                            self.mark_disconnected(s);
                            self.spec[s].active = true;
                        }
                        break;
                    }
                    Ok(Frame::StepRes { step_id: sid, .. }) if sid < step_id => {
                        // Defensive: a stale reply that slipped past the
                        // phase-A drain. Discard and keep waiting.
                        let tr = Arc::clone(&self.transports[s]);
                        handle = pool.submit_deferred(move || tr.lock().unwrap().recv());
                        continue;
                    }
                    Ok(other) => {
                        eprintln!(
                            "[dist] shard {s} sent {} where StepRes was expected",
                            other.name()
                        );
                        self.mark_disconnected(s);
                        self.spec[s].active = true;
                        break;
                    }
                    Err(_) => {
                        self.mark_disconnected(s);
                        self.spec[s].active = true;
                        break;
                    }
                }
            }
        }
        self.speculations += self.spec.iter().filter(|sc| sc.active).count() as u64;

        // -- Phase B: speculative local re-execution of late/lost ranges,
        // from the pre-step mirror state and the coordinator's stream
        // copies — bit-identical to the worker's own execution.
        if self.spec.iter().any(|sc| sc.active) {
            let shared: &dyn PartitionedGs = &*part;
            let rng_slots = &self.rngs;
            pool.run(&mut self.spec, |_k, sc| {
                if !sc.active {
                    return Ok(());
                }
                sc.events.clear();
                for r in sc.rewards.iter_mut() {
                    *r = 0.0;
                }
                // SAFETY: active ranges are disjoint (they partition the
                // agents), each scratch goes to exactly one pool task,
                // in-time ranges' slots are untouched serially during the
                // phase, and the phase barrier ends all views before
                // serial code resumes.
                unsafe {
                    let rs = rng_slots.range_mut(sc.range);
                    shared.step_local(sc.range, actions, &mut sc.rewards, &mut sc.events, rs);
                }
                Ok(())
            })?;
        }

        // -- Phase C: deterministic merge on the mirror, then one-hop
        // scoped sync for the NEXT step.
        for r in rewards.iter_mut() {
            *r = 0.0;
        }
        self.merged.clear();
        for sc in &self.spec {
            self.merged.extend_from_slice(&sc.events);
        }
        self.merged.sort_unstable_by_key(|e| e.key());
        self.outcomes.clear();
        part.apply_boundary_resolved(&self.merged, rewards, Some(&mut self.outcomes));
        debug_assert_eq!(self.outcomes.len(), self.merged.len());

        for v in self.sync_next.iter_mut() {
            v.clear();
        }
        for (e, &applied) in self.merged.iter().zip(self.outcomes.iter()) {
            // An event reaches each consuming shard exactly once, even
            // when both consumers live in the same shard.
            let mut shards = [usize::MAX; 2];
            let mut m = 0;
            for c in e.consumers() {
                let s = self.owner[c];
                if !shards[..m].contains(&s) {
                    shards[m] = s;
                    m += 1;
                }
            }
            if m == 2 {
                debug_assert!(
                    self.adjacent[shards[0]][shards[1]],
                    "event consumers span non-adjacent shards: {e:?}"
                );
            }
            for &s in &shards[..m] {
                if !self.disconnected[s] {
                    self.sync_next[s].push((*e, applied));
                }
            }
        }
        Ok(())
    }

    /// Absorb an in-time worker reply: byte-exact shard state into the
    /// mirror, raw RNG words into the coordinator's stream copies, events
    /// into the merge stash.
    fn import_step_res(
        &mut self,
        part: &mut dyn PartitionedGs,
        s: usize,
        events: Vec<BoundaryEvent>,
        state: &[u8],
        rng_raws: &[(u128, u128)],
    ) -> Result<()> {
        let r = self.ranges[s];
        if rng_raws.len() != r.len() {
            bail!("StepRes carries {} rng streams for a {}-agent shard", rng_raws.len(), r.len());
        }
        part.import_shard_state(r, state)?;
        for (slot, raw) in
            self.rngs.as_mut_slice()[r.start..r.end].iter_mut().zip(rng_raws.iter())
        {
            *slot = Pcg64::from_raw(*raw);
        }
        self.spec[s].events = events;
        Ok(())
    }
}

impl Drop for DistPlan {
    fn drop(&mut self) {
        for s in 0..self.ranges.len() {
            if let Some(h) = self.pending[s].take() {
                let _ = h.wait();
            }
        }
        for (s, t) in self.transports.iter().enumerate() {
            if !self.disconnected[s] {
                let _ = t.lock().unwrap().send(&Frame::Shutdown);
            }
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ShardPlan;

    /// Step a fresh sim T times under the in-process ShardPlan and return
    /// (rewards trace, per-agent obs fingerprint).
    fn reference_trace(
        domain: Domain,
        side: usize,
        shards: usize,
        steps: usize,
    ) -> (Vec<u32>, Vec<u32>) {
        let mut gs = crate::coordinator::make_global_sim(domain, side);
        let n = gs.n_agents();
        let pool = WorkerPool::new(2);
        let mut plan = ShardPlan::new(n, shards);
        let mut rng = Pcg64::seed(77);
        let mut act_rng = Pcg64::seed(5);
        gs.reset(&mut rng);
        plan.reseed(&mut rng);
        let mut rewards = vec![0.0f32; n];
        let mut rtrace = Vec::new();
        let mut actions = vec![0usize; n];
        let n_act = gs.n_actions();
        for _ in 0..steps {
            for a in actions.iter_mut() {
                *a = (act_rng.next_u64() as usize) % n_act;
            }
            plan.step(gs.as_mut(), &pool, &actions, &mut rewards).unwrap();
            for r in &rewards {
                rtrace.push(r.to_bits());
            }
        }
        let mut obs = vec![0.0f32; gs.obs_dim()];
        let mut fp = Vec::new();
        for a in 0..n {
            gs.observe(a, &mut obs);
            fp.extend(obs.iter().map(|x| x.to_bits()));
        }
        (rtrace, fp)
    }

    fn dist_trace(
        domain: Domain,
        side: usize,
        procs: usize,
        steps: usize,
        straggle: Option<StraggleInjection>,
        deadline: Option<Duration>,
    ) -> (Vec<u32>, Vec<u32>, u64) {
        let mut gs = crate::coordinator::make_global_sim(domain, side);
        let n = gs.n_agents();
        let pool = WorkerPool::new(4);
        let mut plan =
            DistPlan::loopback_straggle(procs, domain, side, gs.as_mut(), straggle).unwrap();
        if let Some(d) = deadline {
            plan.set_deadline_override(d);
        }
        let mut rng = Pcg64::seed(77);
        let mut act_rng = Pcg64::seed(5);
        let raw = rng.to_raw();
        gs.reset(&mut rng);
        plan.reseed(raw, &mut rng);
        let mut rewards = vec![0.0f32; n];
        let mut rtrace = Vec::new();
        let mut actions = vec![0usize; n];
        let n_act = gs.n_actions();
        for _ in 0..steps {
            for a in actions.iter_mut() {
                *a = (act_rng.next_u64() as usize) % n_act;
            }
            plan.step(gs.as_mut(), &pool, &actions, &mut rewards).unwrap();
            for r in &rewards {
                rtrace.push(r.to_bits());
            }
        }
        let mut obs = vec![0.0f32; gs.obs_dim()];
        let mut fp = Vec::new();
        for a in 0..n {
            gs.observe(a, &mut obs);
            fp.extend(obs.iter().map(|x| x.to_bits()));
        }
        let specs = plan.speculations();
        drop(plan);
        (rtrace, fp, specs)
    }

    #[test]
    fn loopback_matches_in_process_shards_traffic() {
        let (r_ref, o_ref) = reference_trace(Domain::Traffic, 2, 2, 25);
        for procs in [1usize, 2, 4] {
            let (r, o, _) = dist_trace(Domain::Traffic, 2, procs, 25, None, None);
            assert_eq!(r, r_ref, "traffic rewards diverged at {procs} procs");
            assert_eq!(o, o_ref, "traffic obs diverged at {procs} procs");
        }
    }

    #[test]
    fn loopback_matches_in_process_shards_warehouse() {
        let (r_ref, o_ref) = reference_trace(Domain::Warehouse, 2, 3, 25);
        for procs in [1usize, 3] {
            let (r, o, _) = dist_trace(Domain::Warehouse, 2, procs, 25, None, None);
            assert_eq!(r, r_ref, "warehouse rewards diverged at {procs} procs");
            assert_eq!(o, o_ref, "warehouse obs diverged at {procs} procs");
        }
    }

    #[test]
    fn forced_straggler_speculates_and_stays_bit_identical() {
        let (r_ref, o_ref) = reference_trace(Domain::Traffic, 2, 2, 20);
        let straggle = StraggleInjection { delay_ms: 60, every: 4 };
        let (r, o, specs) = dist_trace(
            Domain::Traffic,
            2,
            2,
            20,
            Some(straggle),
            Some(Duration::from_millis(25)),
        );
        assert!(specs > 0, "the straggle injection must trigger speculation");
        assert_eq!(r, r_ref, "speculation changed the rewards");
        assert_eq!(o, o_ref, "speculation changed the state");
    }

    #[test]
    fn adjacency_is_sparse_on_a_wide_grid() {
        // 4 row-shards on a 4x4 grid: shard 0 touches shard 1 but not 3.
        let mut gs = crate::coordinator::make_global_sim(Domain::Traffic, 4);
        let plan = DistPlan::loopback(4, Domain::Traffic, 4, gs.as_mut()).unwrap();
        assert!(plan.adjacent[0][1]);
        assert!(!plan.adjacent[0][3], "non-neighbouring shards must not be adjacent");
        assert_eq!(plan.n_procs(), 4);
        assert_eq!(plan.n_disconnected(), 0);
    }
}
