//! The shard-worker serve loop behind `dials shard-worker` (DESIGN.md
//! §15).
//!
//! A worker owns one contiguous agent range of a full GS replica. It
//! never sees policies, rewards, or influence labels — per step it
//! receives the scoped actions plus the PREVIOUS step's resolved boundary
//! events, applies those merge decisions to its replica
//! (`PartitionedGs::apply_events_scoped`), runs `step_local` on its range
//! with the owned agents' PCG64 streams, and ships back the emitted
//! events, the byte-exact shard state, and the advanced RNG words. The
//! coordinator performs the deterministic `key()`-ordered merge, so every
//! replica applies the SAME decisions and the trajectory is bit-identical
//! to the in-process `--gs-shards` path at any process count.
//!
//! Determinism of resets: `Reset` carries the raw episode-RNG words
//! captured BEFORE `GlobalSim::reset` on the coordinator. The worker
//! replays the reset draws from the same position, then re-derives ALL
//! `n_agents` per-agent streams in global agent order (`split(k + 1)`,
//! exactly the `ShardPlan::reseed` accounting) and keeps its own range —
//! so stream `k` is the same stream on every process.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::sim::ShardRange;
use crate::util::rng::Pcg64;

use super::transport::ShardTransport;
use super::wire::{Frame, WIRE_VERSION};

/// Test/bench-only artificial straggling: sleep `delay_ms` before every
/// `every`-th step (by 1-based step count). Forces the coordinator's
/// deadline + speculative re-execution path deterministically
/// (`dials shard-worker --straggle-ms --straggle-every`).
#[derive(Clone, Copy, Debug)]
pub struct StraggleInjection {
    pub delay_ms: u64,
    pub every: u64,
}

impl StraggleInjection {
    fn applies_to(&self, step_id: u64) -> bool {
        self.delay_ms > 0 && self.every > 0 && (step_id + 1) % self.every == 0
    }
}

/// Run the worker protocol over `transport` until the coordinator sends
/// `Shutdown` or disconnects (both are clean exits — the coordinator owns
/// the run's lifetime).
pub fn serve(
    transport: &mut dyn ShardTransport,
    straggle: Option<StraggleInjection>,
) -> Result<()> {
    transport.send(&Frame::Hello { version: WIRE_VERSION })?;
    let (domain, grid_side, range, n_agents) = match transport.recv()? {
        Frame::Init { domain, grid_side, start, end, n_agents } => {
            (domain, grid_side, ShardRange { start, end }, n_agents)
        }
        other => bail!("expected Init, got {}", other.name()),
    };
    let mut gs = crate::coordinator::make_global_sim(domain, grid_side);
    if gs.n_agents() != n_agents {
        bail!(
            "Init claims {n_agents} agents but {} at grid side {grid_side} has {}",
            domain.name(),
            gs.n_agents()
        );
    }
    if range.start >= range.end || range.end > n_agents {
        bail!("Init carries invalid shard range [{}, {})", range.start, range.end);
    }

    // Owned-range scratch, reused every step (zero steady-state alloc on
    // the sim side; the wire send owns its own buffers).
    let mut rngs: Vec<Pcg64> = vec![Pcg64::new(0, 0); range.len()];
    let mut actions_full = vec![0usize; n_agents];
    let mut rewards = vec![0.0f32; range.len()];
    let mut events = Vec::new();
    let mut state = Vec::new();
    let mut raws: Vec<(u128, u128)> = Vec::with_capacity(range.len());
    let mut initialised = false;

    loop {
        let frame = match transport.recv() {
            Ok(f) => f,
            // Coordinator gone: normal teardown for socket transports
            // whose peer exits without a Shutdown frame.
            Err(_) => return Ok(()),
        };
        match frame {
            Frame::Reset { state: s, inc } => {
                let mut episode = Pcg64::from_raw((s, inc));
                gs.reset(&mut episode);
                // Global-order stream derivation; keep the owned range.
                for k in 0..n_agents {
                    let stream = episode.split(k as u64 + 1);
                    if range.contains(k) {
                        rngs[k - range.start] = stream;
                    }
                }
                initialised = true;
            }
            Frame::Step { step_id, actions, sync } => {
                if !initialised {
                    bail!("Step before any Reset");
                }
                if actions.len() != range.len() {
                    bail!(
                        "Step carries {} actions for a {}-agent shard",
                        actions.len(),
                        range.len()
                    );
                }
                if let Some(s) = &straggle {
                    if s.applies_to(step_id) {
                        std::thread::sleep(Duration::from_millis(s.delay_ms));
                    }
                }
                let part = gs
                    .as_partitioned()
                    .ok_or_else(|| anyhow!("{} GS is not partitioned", domain.name()))?;
                // Complete the previous tick with the coordinator's merge
                // decisions, then advance the owned range one tick.
                part.apply_events_scoped(&sync, range);
                for (k, a) in actions.iter().enumerate() {
                    actions_full[range.start + k] = *a as usize;
                }
                for r in rewards.iter_mut() {
                    *r = 0.0;
                }
                events.clear();
                // SAFETY: this thread is the only accessor of `gs`; the
                // single range trivially satisfies the disjointness
                // contract.
                unsafe {
                    part.step_local(range, &actions_full, &mut rewards, &mut events, &mut rngs);
                }
                state.clear();
                part.export_shard_state(range, &mut state);
                raws.clear();
                raws.extend(rngs.iter().map(|r| r.to_raw()));
                transport.send(&Frame::StepRes {
                    step_id,
                    events: events.clone(),
                    state: state.clone(),
                    rngs: raws.clone(),
                })?;
            }
            Frame::Shutdown => return Ok(()),
            other => bail!("unexpected {} frame in the serve loop", other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Domain;
    use crate::dist::transport::ChannelTransport;

    /// Drive one worker thread through handshake, reset, and a step; the
    /// full coordinator-equivalence suite lives in
    /// `tests/dist_equivalence.rs`.
    #[test]
    fn worker_handshakes_resets_and_steps() {
        let (mut coord, worker) = ChannelTransport::pair();
        let h = std::thread::spawn(move || {
            let mut t = worker;
            serve(&mut t, None)
        });
        match coord.recv().unwrap() {
            Frame::Hello { version } => assert_eq!(version, WIRE_VERSION),
            other => panic!("expected Hello, got {}", other.name()),
        }
        coord
            .send(&Frame::Init { domain: Domain::Traffic, grid_side: 2, start: 0, end: 2, n_agents: 4 })
            .unwrap();
        let rng = Pcg64::seed(11);
        coord.send(&Frame::Reset { state: rng.to_raw().0, inc: rng.to_raw().1 }).unwrap();
        coord
            .send(&Frame::Step { step_id: 0, actions: vec![0, 1], sync: Vec::new() })
            .unwrap();
        match coord.recv().unwrap() {
            Frame::StepRes { step_id, state, rngs, .. } => {
                assert_eq!(step_id, 0);
                assert!(!state.is_empty());
                assert_eq!(rngs.len(), 2);
            }
            other => panic!("expected StepRes, got {}", other.name()),
        }
        coord.send(&Frame::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn worker_rejects_step_before_reset() {
        let (mut coord, worker) = ChannelTransport::pair();
        let h = std::thread::spawn(move || {
            let mut t = worker;
            serve(&mut t, None)
        });
        let _ = coord.recv().unwrap(); // Hello
        coord
            .send(&Frame::Init { domain: Domain::Warehouse, grid_side: 2, start: 2, end: 4, n_agents: 4 })
            .unwrap();
        coord.send(&Frame::Step { step_id: 0, actions: vec![0, 0], sync: Vec::new() }).unwrap();
        let err = h.join().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("before any Reset"), "{err:#}");
    }

    #[test]
    fn worker_rejects_bad_init() {
        for bad in [
            Frame::Init { domain: Domain::Traffic, grid_side: 2, start: 0, end: 9, n_agents: 4 },
            Frame::Init { domain: Domain::Traffic, grid_side: 2, start: 3, end: 3, n_agents: 4 },
            Frame::Init { domain: Domain::Traffic, grid_side: 2, start: 0, end: 4, n_agents: 5 },
        ] {
            let (mut coord, worker) = ChannelTransport::pair();
            let h = std::thread::spawn(move || {
                let mut t = worker;
                serve(&mut t, None)
            });
            let _ = coord.recv().unwrap(); // Hello
            coord.send(&bad).unwrap();
            assert!(h.join().unwrap().is_err(), "worker accepted {bad:?}");
        }
    }

    #[test]
    fn straggle_schedule_fires_every_nth_step() {
        let s = StraggleInjection { delay_ms: 5, every: 3 };
        let fired: Vec<u64> = (0..9).filter(|&t| s.applies_to(t)).collect();
        assert_eq!(fired, vec![2, 5, 8]);
        let off = StraggleInjection { delay_ms: 0, every: 3 };
        assert!(!(0..9).any(|t| off.applies_to(t)));
    }
}
