//! Multi-process GS sharding (DESIGN.md §15).
//!
//! Promotes the `sim::PartitionedGs` scatter/merge protocol from a thread
//! boundary to a PROCESS boundary: `P` shard-worker processes (`dials
//! shard-worker`) each own a contiguous agent range of a full GS replica
//! and run the shard-local phase, while one coordinator performs the
//! deterministic `key()`-ordered merge on its authoritative mirror and
//! ships each resolved boundary-event batch only to the shards whose
//! agents consume it (one-hop scoping, after DARL1N, Wang et al. 2022).
//!
//! Layers:
//! * [`wire`] — dependency-free binary frame codec ([`Frame`],
//!   [`WIRE_VERSION`]);
//! * [`transport`] — [`ShardTransport`]: mpsc loopback
//!   ([`ChannelTransport`]) and TCP/Unix sockets ([`SocketTransport`],
//!   [`ShardListener`]) with length-prefixed frames, read timeouts, and
//!   reconnect backoff;
//! * [`worker`] — the shard-worker serve loop;
//! * [`plan`] — [`DistPlan`]: the coordinator driver with EWMA step
//!   deadlines and speculative local re-execution of stragglers.
//!
//! The distributed path is pinned bit-identical to the in-process
//! `--gs-shards` path at any process count, including under injected
//! straggler delay and worker loss (`tests/dist_equivalence.rs`,
//! `tests/dist_smoke.rs`).

pub mod plan;
pub mod transport;
pub mod wire;
pub mod worker;

pub use plan::DistPlan;
pub use transport::{ChannelTransport, ShardListener, ShardTransport, SocketTransport};
pub use wire::{Frame, WIRE_VERSION};
pub use worker::{serve, StraggleInjection};
