//! The shard wire protocol (DESIGN.md §15).
//!
//! One coordinator process drives `P` shard-worker processes; every
//! message between them is one [`Frame`], encoded with the dependency-free
//! little-endian codec (`util::codec`). Frames are self-describing (tag
//! byte first) and framed by the transport with a `u32` length prefix, so
//! the codec layer never needs to guess where a message ends.
//!
//! Handshake: the worker sends `Hello{version}` as soon as it connects;
//! the coordinator verifies [`WIRE_VERSION`] and replies `Init` with the
//! domain, the grid side, and the worker's owned agent range. After that
//! the coordinator speaks `Reset`/`Step`/`Shutdown` and the worker answers
//! every `Step` with exactly one `StepRes`.
//!
//! Decoding errors (truncation, unknown tags, absurd counts) surface as
//! `Err` — never a panic — so a malformed or cut-off frame cannot take the
//! coordinator down (`tests/dist_transport.rs` cuts frames at every byte
//! offset to pin this).

use anyhow::{bail, Result};

use crate::config::Domain;
use crate::sim::BoundaryEvent;
use crate::util::codec::{ByteReader, ByteWriter};

/// Bumped on any incompatible change to the frame layout. The coordinator
/// refuses a `Hello` carrying a different version instead of misreading
/// frames from a stale binary.
pub const WIRE_VERSION: u32 = 1;

/// One message of the coordinator <-> shard-worker protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker -> coordinator, immediately after connecting.
    Hello { version: u32 },
    /// Coordinator -> worker: build this domain's GS at `grid_side` and
    /// own the contiguous agent rows `[start, end)` of `n_agents`.
    Init { domain: Domain, grid_side: usize, start: usize, end: usize, n_agents: usize },
    /// Coordinator -> worker: replay an episode reset. Carries the raw
    /// PCG64 words of the episode RNG captured BEFORE `GlobalSim::reset`,
    /// so the worker reproduces the reset draws and the per-agent stream
    /// derivation bit-exactly (`Pcg64::from_raw`).
    Reset { state: u128, inc: u128 },
    /// Coordinator -> worker: advance the owned range one tick. `actions`
    /// is scoped to `[start, end)`; `sync` carries the PREVIOUS step's
    /// merged boundary events — resolved `(event, applied)` pairs already
    /// scoped to this shard's consumers — which the worker applies via
    /// `PartitionedGs::apply_events_scoped` before stepping.
    Step { step_id: u64, actions: Vec<u32>, sync: Vec<(BoundaryEvent, bool)> },
    /// Worker -> coordinator: the result of one `Step`. `events` are the
    /// boundary events emitted by `step_local`, `state` the byte-exact
    /// shard state (`PartitionedGs::export_shard_state`), and `rngs` the
    /// raw words of the owned agents' PCG64 streams after the tick.
    StepRes { step_id: u64, events: Vec<BoundaryEvent>, state: Vec<u8>, rngs: Vec<(u128, u128)> },
    /// Coordinator -> worker: exit the serve loop.
    Shutdown,
}

/// Ceiling on any element count read off the wire before its payload is
/// length-checked — purely a defence against a corrupt count causing an
/// absurd allocation (the per-element size checks below are the real
/// validation).
const MAX_WIRE_ELEMS: usize = 1 << 24;

impl Frame {
    /// Human-readable frame name for protocol error messages.
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::Init { .. } => "Init",
            Frame::Reset { .. } => "Reset",
            Frame::Step { .. } => "Step",
            Frame::StepRes { .. } => "StepRes",
            Frame::Shutdown => "Shutdown",
        }
    }

    /// Append the frame's wire form to `buf` (tag byte first).
    pub fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Frame::Hello { version } => {
                let mut w = ByteWriter::new(buf);
                w.put_u8(0);
                w.put_u32(*version);
            }
            Frame::Init { domain, grid_side, start, end, n_agents } => {
                let mut w = ByteWriter::new(buf);
                w.put_u8(1);
                w.put_u8(match domain {
                    Domain::Traffic => 0,
                    Domain::Warehouse => 1,
                });
                w.put_u32(*grid_side as u32);
                w.put_u32(*start as u32);
                w.put_u32(*end as u32);
                w.put_u32(*n_agents as u32);
            }
            Frame::Reset { state, inc } => {
                let mut w = ByteWriter::new(buf);
                w.put_u8(2);
                w.put_u128(*state);
                w.put_u128(*inc);
            }
            Frame::Step { step_id, actions, sync } => {
                {
                    let mut w = ByteWriter::new(buf);
                    w.put_u8(3);
                    w.put_u64(*step_id);
                    w.put_u32(actions.len() as u32);
                    for a in actions {
                        w.put_u32(*a);
                    }
                    w.put_u32(sync.len() as u32);
                }
                for (e, applied) in sync {
                    e.encode(buf);
                    buf.push(u8::from(*applied));
                }
            }
            Frame::StepRes { step_id, events, state, rngs } => {
                {
                    let mut w = ByteWriter::new(buf);
                    w.put_u8(4);
                    w.put_u64(*step_id);
                    w.put_u32(events.len() as u32);
                }
                for e in events {
                    e.encode(buf);
                }
                let mut w = ByteWriter::new(buf);
                w.put_bytes(state);
                w.put_u32(rngs.len() as u32);
                for (s, i) in rngs {
                    w.put_u128(*s);
                    w.put_u128(*i);
                }
            }
            Frame::Shutdown => buf.push(5),
        }
    }

    /// Decode one frame from its exact wire body (inverse of `encode`).
    /// Errors on truncation, trailing garbage, unknown tags, or counts
    /// that cannot fit the remaining bytes; never panics.
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        let mut r = ByteReader::new(bytes);
        let frame = match r.get_u8()? {
            0 => Frame::Hello { version: r.get_u32()? },
            1 => {
                let domain = match r.get_u8()? {
                    0 => Domain::Traffic,
                    1 => Domain::Warehouse,
                    d => bail!("unknown domain tag {d}"),
                };
                Frame::Init {
                    domain,
                    grid_side: r.get_u32()? as usize,
                    start: r.get_u32()? as usize,
                    end: r.get_u32()? as usize,
                    n_agents: r.get_u32()? as usize,
                }
            }
            2 => Frame::Reset { state: r.get_u128()?, inc: r.get_u128()? },
            3 => {
                let step_id = r.get_u64()?;
                let n_act = r.get_u32()? as usize;
                let n_act = checked_count(&r, n_act, 4, "actions")?;
                let mut actions = Vec::with_capacity(n_act);
                for _ in 0..n_act {
                    actions.push(r.get_u32()?);
                }
                // Smallest sync entry: tag + two u32 fields + applied flag.
                let n_sync = r.get_u32()? as usize;
                let n_sync = checked_count(&r, n_sync, 10, "sync events")?;
                let mut sync = Vec::with_capacity(n_sync);
                for _ in 0..n_sync {
                    let e = BoundaryEvent::decode(&mut r)?;
                    let applied = match r.get_u8()? {
                        0 => false,
                        1 => true,
                        b => bail!("bad sync outcome flag {b}"),
                    };
                    sync.push((e, applied));
                }
                Frame::Step { step_id, actions, sync }
            }
            4 => {
                let step_id = r.get_u64()?;
                // Smallest event: tag + two u32 fields.
                let n_ev = r.get_u32()? as usize;
                let n_ev = checked_count(&r, n_ev, 9, "events")?;
                let mut events = Vec::with_capacity(n_ev);
                for _ in 0..n_ev {
                    events.push(BoundaryEvent::decode(&mut r)?);
                }
                let state = r.get_bytes()?.to_vec();
                let n_rng = r.get_u32()? as usize;
                let n_rng = checked_count(&r, n_rng, 32, "rng streams")?;
                let mut rngs = Vec::with_capacity(n_rng);
                for _ in 0..n_rng {
                    let s = r.get_u128()?;
                    let i = r.get_u128()?;
                    rngs.push((s, i));
                }
                Frame::StepRes { step_id, events, state, rngs }
            }
            5 => Frame::Shutdown,
            tag => bail!("unknown frame tag {tag}"),
        };
        if r.remaining() != 0 {
            bail!("{} trailing bytes after {} frame", r.remaining(), frame.name());
        }
        Ok(frame)
    }
}

/// Validate an element count read off the wire: each element needs at
/// least `min_size` bytes, so a count the remaining payload cannot hold is
/// a corrupt frame (and must error before any allocation happens).
fn checked_count(r: &ByteReader<'_>, n: usize, min_size: usize, what: &str) -> Result<usize> {
    if n > MAX_WIRE_ELEMS || n.saturating_mul(min_size) > r.remaining() {
        bail!("frame claims {n} {what} but only {} payload bytes remain", r.remaining());
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        f.encode(&mut buf);
        Frame::decode(&buf).expect("roundtrip decode")
    }

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { version: WIRE_VERSION },
            Frame::Init {
                domain: Domain::Warehouse,
                grid_side: 3,
                start: 4,
                end: 9,
                n_agents: 9,
            },
            Frame::Reset { state: 0xDEAD_BEEF_DEAD_BEEF_0123_4567_89AB_CDEF, inc: 42 },
            Frame::Step {
                step_id: 7,
                actions: vec![0, 3, 1],
                sync: vec![
                    (
                        BoundaryEvent::TrafficCross { agent: 1, lane: 2, src: 0, src_lane: 3 },
                        true,
                    ),
                    (BoundaryEvent::TrafficInflow { agent: 2, lane: 0 }, false),
                    (BoundaryEvent::WarehouseSpawn { agent: 0, slot: 5 }, true),
                ],
            },
            Frame::StepRes {
                step_id: 7,
                events: vec![BoundaryEvent::TrafficInflow { agent: 1, lane: 3 }],
                state: vec![1, 2, 3, 255, 0],
                rngs: vec![(u128::MAX, 1), (2, 3)],
            },
            Frame::Shutdown,
        ]
    }

    #[test]
    fn every_frame_roundtrips() {
        for f in sample_frames() {
            assert_eq!(roundtrip(&f), f);
        }
        // Empty collections roundtrip too.
        let empty = Frame::Step { step_id: 0, actions: Vec::new(), sync: Vec::new() };
        assert_eq!(roundtrip(&empty), empty);
        let empty_res =
            Frame::StepRes { step_id: 0, events: Vec::new(), state: Vec::new(), rngs: Vec::new() };
        assert_eq!(roundtrip(&empty_res), empty_res);
    }

    #[test]
    fn truncation_at_every_offset_errors() {
        for f in sample_frames() {
            let mut buf = Vec::new();
            f.encode(&mut buf);
            for cut in 0..buf.len() {
                assert!(
                    Frame::decode(&buf[..cut]).is_err(),
                    "{} cut to {cut}/{} bytes must not decode",
                    f.name(),
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut buf = Vec::new();
        Frame::Shutdown.encode(&mut buf);
        buf.push(0);
        assert!(Frame::decode(&buf).is_err());
    }

    #[test]
    fn corrupt_counts_and_tags_error_without_panicking() {
        assert!(Frame::decode(&[]).is_err());
        assert!(Frame::decode(&[99]).is_err(), "unknown frame tag");
        assert!(Frame::decode(&[1, 7]).is_err(), "unknown domain tag");
        // A Step frame whose action count exceeds the payload must error
        // before it allocates.
        let mut buf = Vec::new();
        {
            let mut w = ByteWriter::new(&mut buf);
            w.put_u8(3);
            w.put_u64(0);
            w.put_u32(u32::MAX);
        }
        assert!(Frame::decode(&buf).is_err());
        // Same for a StepRes rng count.
        let mut buf = Vec::new();
        {
            let mut w = ByteWriter::new(&mut buf);
            w.put_u8(4);
            w.put_u64(0);
            w.put_u32(0); // events
            w.put_u32(0); // state bytes
            w.put_u32(1 << 30); // rng streams
        }
        assert!(Frame::decode(&buf).is_err());
    }

    #[test]
    fn version_mismatch_is_representable() {
        // The coordinator-side check compares against WIRE_VERSION; pin
        // that the field survives the wire untouched.
        match roundtrip(&Frame::Hello { version: WIRE_VERSION + 1 }) {
            Frame::Hello { version } => assert_eq!(version, WIRE_VERSION + 1),
            other => panic!("wrong frame {}", other.name()),
        }
    }
}
