"""L2 correctness: policies, AIPs, PPO and AIP updates.

Checks shapes, probability invariants, loss values against hand-rolled
references, and that Adam-in-graph actually descends.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


TRAFFIC_POL = M.PolicySpec(27, 2, False, 16, 16)
WARE_POL = M.PolicySpec(37, 5, True, 16, 16)
TRAFFIC_AIP = M.AipSpec(29, False, 16, 4, 1)
WARE_AIP = M.AipSpec(42, True, 16, 4, 4)


def _flat_policy(spec, seed=0):
    params = M.init_policy(jax.random.PRNGKey(seed), spec)
    return M.flatten_params(params)


def _flat_aip(spec, seed=0):
    params = M.init_aip(jax.random.PRNGKey(seed), spec)
    return M.flatten_params(params)


def _unpack_step(spec, packed):
    """Split the packed [logits|value|h'] artifact output."""
    a = spec.act
    return packed[:a], packed[a], packed[a + 1 :]


@pytest.mark.parametrize("spec", [TRAFFIC_POL, WARE_POL], ids=["fnn", "gru"])
def test_policy_step_shapes(spec):
    flat, unravel = _flat_policy(spec)
    step = M.make_policy_step(spec, unravel)
    obs = jnp.ones((1, spec.obs))
    h = jnp.zeros((1, spec.hstate))
    packed = step(flat, obs, h)
    assert packed.shape == (spec.act + 1 + spec.hstate,)
    logits, value, h2 = _unpack_step(spec, packed)
    assert logits.shape == (spec.act,)
    assert h2.shape == (spec.hstate,)
    assert np.all(np.isfinite(np.asarray(packed)))
    assert np.isfinite(float(value))


def test_fnn_policy_ignores_hidden_state():
    spec = TRAFFIC_POL
    flat, unravel = _flat_policy(spec)
    step = M.make_policy_step(spec, unravel)
    obs = jnp.ones((1, spec.obs))
    p1 = step(flat, obs, jnp.zeros((1, 1)))
    p2 = step(flat, obs, jnp.full((1, 1), 9.0))
    np.testing.assert_allclose(p1[: spec.act + 1], p2[: spec.act + 1])


def test_gru_policy_state_carries_information():
    spec = WARE_POL
    flat, unravel = _flat_policy(spec)
    step = M.make_policy_step(spec, unravel)
    obs = jnp.ones((1, spec.obs))
    _, _, h1 = _unpack_step(spec, step(flat, obs, jnp.zeros((1, spec.hstate))))
    l_a, _, _ = _unpack_step(spec, step(flat, obs, h1[None, :]))
    l_b, _, _ = _unpack_step(spec, step(flat, obs, jnp.zeros((1, spec.hstate))))
    assert not np.allclose(l_a, l_b)


@pytest.mark.parametrize("spec", [TRAFFIC_AIP, WARE_AIP], ids=["fnn", "gru"])
def test_aip_forward_probabilities(spec):
    flat, unravel = _flat_aip(spec)
    fwd = M.make_aip_forward(spec, unravel)
    feat = jnp.ones((1, spec.feat)) * 0.3
    h = jnp.zeros((1, spec.hstate))
    packed = fwd(flat, feat, h)  # [probs | h']
    assert packed.shape == (spec.u_dim + spec.hstate,)
    p = np.asarray(packed[: spec.u_dim])
    assert np.all(p >= 0) and np.all(p <= 1)
    if spec.n_cls > 1:
        groups = p.reshape(spec.n_heads, spec.n_cls)
        np.testing.assert_allclose(groups.sum(axis=1), 1.0, rtol=1e-5)


def test_ppo_loss_matches_manual():
    spec = TRAFFIC_POL
    cfg = M.PpoCfg()
    flat, unravel = _flat_policy(spec)
    params = unravel(flat)
    rng = np.random.default_rng(0)
    mb = 8
    obs = jnp.asarray(rng.standard_normal((mb, spec.obs)), jnp.float32)
    h0 = jnp.zeros((mb, 1))
    act = jnp.asarray(rng.integers(0, spec.act, mb), jnp.float32)
    old_logp = jnp.asarray(rng.standard_normal(mb) * 0.1 - 0.7, jnp.float32)
    adv = jnp.asarray(rng.standard_normal(mb), jnp.float32)
    ret = jnp.asarray(rng.standard_normal(mb), jnp.float32)

    total, (pg, vl, ent) = M.ppo_loss(params, spec, cfg, obs, h0, act, old_logp, adv, ret)

    logits, value, _ = M.policy_apply(params, spec, obs, h0)
    logp_all = np.asarray(jax.nn.log_softmax(logits))
    a = np.asarray(act, np.int32)
    logp = logp_all[np.arange(mb), a]
    ratio = np.exp(logp - np.asarray(old_logp))
    clipped = np.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps)
    pg_m = -np.mean(np.minimum(ratio * np.asarray(adv), clipped * np.asarray(adv)))
    vl_m = np.mean((np.asarray(value) - np.asarray(ret)) ** 2)
    probs = np.exp(logp_all)
    ent_m = -np.mean(np.sum(probs * logp_all, axis=1))
    np.testing.assert_allclose(pg, pg_m, rtol=1e-5)
    np.testing.assert_allclose(vl, vl_m, rtol=1e-5)
    np.testing.assert_allclose(ent, ent_m, rtol=1e-5)
    np.testing.assert_allclose(total, pg_m + cfg.vf_coef * vl_m - cfg.ent_coef * ent_m, rtol=1e-5)


@pytest.mark.parametrize("spec", [TRAFFIC_POL, WARE_POL], ids=["fnn", "gru"])
def test_ppo_update_descends(spec):
    cfg = M.PpoCfg()
    flat, unravel = _flat_policy(spec)
    pdim = flat.shape[0]
    mb = 16
    upd = jax.jit(M.make_ppo_update(spec, cfg, unravel, pdim, mb))
    rng = np.random.default_rng(1)
    obs = jnp.asarray(rng.standard_normal((mb, spec.obs)), jnp.float32)
    h0 = jnp.zeros((mb, spec.hstate))
    act = jnp.asarray(rng.integers(0, spec.act, mb), jnp.float32)
    # old_logp consistent with the current policy (ratio starts at 1).
    logits, _, _ = M.policy_apply(unravel(flat), spec, obs, h0)
    logp_all = jax.nn.log_softmax(logits)
    old_logp = jnp.take_along_axis(logp_all, act.astype(jnp.int32)[:, None], 1)[:, 0]
    adv = jnp.asarray(rng.standard_normal(mb), jnp.float32)
    ret = jnp.asarray(rng.standard_normal(mb), jnp.float32)

    # packed [flat|m|v|metrics] state + packed [t|obs|h|act|logp|adv|ret] batch
    state = jnp.concatenate([flat, jnp.zeros(2 * pdim + 4, jnp.float32)])
    losses = []
    for t in range(1, 15):
        batch = jnp.concatenate([
            jnp.asarray([float(t)]), obs.ravel(), h0.ravel(),
            act, old_logp, adv, ret,
        ])
        state = upd(state, batch)
        losses.append(float(state[3 * pdim]))
    assert losses[-1] < losses[0], f"no descent: {losses[0]} -> {losses[-1]}"
    assert np.all(np.isfinite(np.asarray(state)))


@pytest.mark.parametrize("spec", [TRAFFIC_POL, WARE_POL], ids=["fnn", "gru"])
def test_ppo_update_b_matches_per_agent_rows(spec):
    """The fused [N]-wide update is the per-agent update per row.

    vmap batches the matmuls, so the lowered numerics are allclose
    (f32-reassociation tolerance), not bitwise — bit-identity is the
    native backend's contract (rust/tests/native_training.rs).
    """
    cfg = M.PpoCfg()
    flat, unravel = _flat_policy(spec)
    pdim = flat.shape[0]
    mb, n = 4, 3
    upd = jax.jit(M.make_ppo_update(spec, cfg, unravel, pdim, mb))
    upd_b = jax.jit(M.make_ppo_update_b(spec, cfg, unravel, pdim, mb))
    rng = np.random.default_rng(3)
    d, h = spec.obs, spec.hstate

    def mk_batch(t):
        return jnp.concatenate([
            jnp.asarray([float(t)]),
            jnp.asarray(rng.standard_normal(mb * d), jnp.float32),
            jnp.asarray(0.5 * rng.standard_normal(mb * h), jnp.float32),
            jnp.asarray(rng.integers(0, spec.act, mb), jnp.float32),
            jnp.asarray(-np.log(spec.act) + 0.1 * rng.standard_normal(mb), jnp.float32),
            jnp.asarray(rng.standard_normal(mb), jnp.float32),
            jnp.asarray(rng.standard_normal(mb), jnp.float32),
        ])

    states = jnp.stack([
        jnp.concatenate([
            _flat_policy(spec, seed=i + 1)[0], jnp.zeros(2 * pdim + 4, jnp.float32),
        ])
        for i in range(n)
    ])
    seq = states
    fused = states
    # Chained minibatch steps: Adam moments and params must track too.
    for t in range(1, 4):
        batches = jnp.stack([mk_batch(t) for _ in range(n)])
        seq = jnp.stack([upd(seq[i], batches[i]) for i in range(n)])
        fused = upd_b(fused, batches)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq), rtol=1e-4, atol=1e-6)
    assert np.all(np.isfinite(np.asarray(fused)))
    # The update did move the params.
    assert not np.array_equal(np.asarray(fused[:, :pdim]), np.asarray(states[:, :pdim]))


@pytest.mark.parametrize("spec,seq", [(TRAFFIC_AIP, 1), (WARE_AIP, 5)], ids=["fnn", "gru"])
def test_aip_update_descends(spec, seq):
    flat, unravel = _flat_aip(spec)
    adim = flat.shape[0]
    rng = np.random.default_rng(2)
    b = 16
    if spec.recurrent:
        fshape, lshape = (b, seq, spec.feat), (b, seq, spec.n_heads)
        feats = jnp.asarray(rng.standard_normal(fshape), jnp.float32)
        labels = jnp.asarray(rng.integers(0, spec.n_cls, lshape), jnp.float32)
    else:
        fshape, lshape = (b, spec.feat), (b, spec.n_heads)
        feats = jnp.asarray(rng.standard_normal(fshape), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 2, lshape), jnp.float32)
    upd = jax.jit(M.make_aip_update(spec, M.AdamCfg(lr=3e-3), unravel, adim, fshape, lshape))
    state = jnp.concatenate([flat, jnp.zeros(2 * adim + 1, jnp.float32)])
    ces = []
    for t in range(1, 30):
        batch = jnp.concatenate([jnp.asarray([float(t)]), feats.ravel(), labels.ravel()])
        state = upd(state, batch)
        ces.append(float(state[3 * adim]))
    assert ces[-1] < ces[0], f"CE did not descend: {ces[0]} -> {ces[-1]}"


@pytest.mark.parametrize("spec,seq", [(TRAFFIC_AIP, 1), (WARE_AIP, 5)], ids=["fnn", "gru"])
def test_aip_update_b_matches_per_agent_rows(spec, seq):
    """The fused [N]-wide AIP update is the per-agent update per row.

    Same contract as test_ppo_update_b_matches_per_agent_rows: allclose
    under vmap's matmul re-batching; bitwise identity is the native
    backend's job (rust/tests/native_retrain.rs).
    """
    flat, unravel = _flat_aip(spec)
    adim = flat.shape[0]
    b, n = 4, 3
    if spec.recurrent:
        fshape, lshape = (b, seq, spec.feat), (b, seq, spec.n_heads)
        label_hi = spec.n_cls
    else:
        fshape, lshape = (b, spec.feat), (b, spec.n_heads)
        label_hi = 2
    adam = M.AdamCfg(lr=3e-3)
    upd = jax.jit(M.make_aip_update(spec, adam, unravel, adim, fshape, lshape))
    upd_b = jax.jit(M.make_aip_update_b(spec, adam, unravel, adim, fshape, lshape))
    rng = np.random.default_rng(4)

    def mk_batch(t):
        feats = rng.standard_normal(fshape).astype(np.float32)
        labels = rng.integers(0, label_hi, lshape).astype(np.float32)
        return jnp.concatenate([jnp.asarray([float(t)]), jnp.ravel(feats), jnp.ravel(labels)])

    states = jnp.stack([
        jnp.concatenate([
            _flat_aip(spec, seed=i + 1)[0], jnp.zeros(2 * adim + 1, jnp.float32),
        ])
        for i in range(n)
    ])
    seq_s = states
    fused = states
    # Chained epochs: Adam moments, params, and the CE tail must track.
    for t in range(1, 4):
        batches = jnp.stack([mk_batch(t) for _ in range(n)])
        seq_s = jnp.stack([upd(seq_s[i], batches[i]) for i in range(n)])
        fused = upd_b(fused, batches)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq_s), rtol=1e-4, atol=1e-6)
    assert np.all(np.isfinite(np.asarray(fused)))
    assert not np.array_equal(np.asarray(fused[:, :adim]), np.asarray(states[:, :adim]))


def test_aip_ce_loss_matches_manual_bernoulli():
    spec = TRAFFIC_AIP
    flat, unravel = _flat_aip(spec)
    params = unravel(flat)
    rng = np.random.default_rng(3)
    b = 8
    feats = jnp.asarray(rng.standard_normal((b, spec.feat)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 2, (b, spec.n_heads)), jnp.float32)
    ce = float(M.aip_ce_loss(params, spec, feats, labels))
    probs, _ = M.aip_apply(params, spec, feats, jnp.zeros((b, 1)))
    p = np.clip(np.asarray(probs), 1e-7, 1 - 1e-7)
    y = np.asarray(labels)
    manual = -np.mean(y * np.log(p) + (1 - y) * np.log(1 - p))
    np.testing.assert_allclose(ce, manual, rtol=1e-4)


def test_adam_step_matches_reference():
    cfg = M.AdamCfg(lr=1e-2)
    flat = jnp.asarray([1.0, -2.0, 3.0])
    m = jnp.zeros(3)
    v = jnp.zeros(3)
    g = jnp.asarray([0.5, -0.5, 1.0])
    f2, m2, v2 = M.adam_step(flat, m, v, g, jnp.asarray([1.0]), cfg)
    m_ref = 0.1 * np.asarray(g)
    v_ref = 0.001 * np.asarray(g) ** 2
    mh = m_ref / (1 - 0.9)
    vh = v_ref / (1 - 0.999)
    f_ref = np.asarray(flat) - cfg.lr * mh / (np.sqrt(vh) + cfg.eps)
    np.testing.assert_allclose(f2, f_ref, rtol=1e-5)
    np.testing.assert_allclose(m2, m_ref, rtol=1e-6)
    np.testing.assert_allclose(v2, v_ref, rtol=1e-6)


def test_grad_clip_limits_norm():
    g = jnp.full((100,), 10.0)
    clipped = M._clip_by_global_norm(g, 0.5)
    assert abs(float(jnp.sqrt(jnp.sum(clipped**2))) - 0.5) < 1e-4
    g_small = jnp.full((4,), 1e-3)
    np.testing.assert_allclose(M._clip_by_global_norm(g_small, 0.5), g_small, rtol=1e-5)


# ---------------------------------------------------------------- batched


@pytest.mark.parametrize("spec", [TRAFFIC_POL, WARE_POL], ids=["fnn", "gru"])
def test_batched_policy_step_matches_b1_rows(spec):
    """The joint-step artifact is a vmap of the B=1 row: per-row numerics
    must match make_policy_step exactly (the Rust banks rely on this)."""
    flat, unravel = _flat_policy(spec)
    step = M.make_policy_step(spec, unravel)
    step_b = M.make_policy_step_batched(spec, unravel)
    n = 3
    rng = np.random.default_rng(0)
    flats = jnp.stack([flat * (1.0 + 0.1 * i) for i in range(n)])
    obs = jnp.asarray(rng.standard_normal((n, spec.obs)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((n, spec.hstate)), jnp.float32)
    packed_b = step_b(flats, obs, h)
    assert packed_b.shape == (n, spec.act + 1 + spec.hstate)
    for i in range(n):
        row = step(flats[i], obs[i][None, :], h[i][None, :])
        np.testing.assert_allclose(np.asarray(packed_b[i]), np.asarray(row), atol=1e-5)


@pytest.mark.parametrize("spec", [TRAFFIC_AIP, WARE_AIP], ids=["fnn", "gru"])
def test_batched_aip_forward_matches_b1_rows(spec):
    flat, unravel = _flat_aip(spec)
    fwd = M.make_aip_forward(spec, unravel)
    fwd_b = M.make_aip_forward_batched(spec, unravel)
    n = 3
    rng = np.random.default_rng(1)
    flats = jnp.stack([flat * (1.0 + 0.1 * i) for i in range(n)])
    feats = jnp.asarray(rng.standard_normal((n, spec.feat)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((n, spec.hstate)), jnp.float32)
    packed_b = fwd_b(flats, feats, h)
    assert packed_b.shape == (n, spec.u_dim + spec.hstate)
    for i in range(n):
        row = fwd(flats[i], feats[i][None, :], h[i][None, :])
        np.testing.assert_allclose(np.asarray(packed_b[i]), np.asarray(row), atol=1e-5)


def test_flat_layout():
    """Pin the ravel_pytree flat layout the Rust native backend decodes
    (rust/src/runtime/layout.rs): top-level layers in sorted name order,
    dense = b|w (w row-major [in][out]), gru = bh|bx|wh|wx."""
    spec = M.PolicySpec(2, 1, False, 2, 2)
    params = {
        "fc1": {"w": jnp.full((2, 2), 1.0), "b": jnp.full((2,), 2.0)},
        "fc2": {"w": jnp.full((2, 2), 3.0), "b": jnp.full((2,), 4.0)},
        "pi": {"w": jnp.full((2, 1), 5.0), "b": jnp.full((1,), 6.0)},
        "vf": {"w": jnp.full((2, 1), 7.0), "b": jnp.full((1,), 8.0)},
    }
    flat, _ = M.flatten_params(params)
    expect = [2, 2, 1, 1, 1, 1, 4, 4, 3, 3, 3, 3, 6, 5, 5, 8, 7, 7]
    assert np.asarray(flat).astype(int).tolist() == expect
    del spec

    gru = {
        "gru": {
            "wx": jnp.full((1, 3), 1.0),
            "wh": jnp.full((1, 3), 2.0),
            "bx": jnp.full((3,), 3.0),
            "bh": jnp.full((3,), 4.0),
        }
    }
    flat_g, _ = M.flatten_params(gru)
    assert np.asarray(flat_g).astype(int).tolist() == [4, 4, 4, 3, 3, 3, 2, 2, 2, 1, 1, 1]
