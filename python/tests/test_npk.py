"""Pin the NPK tensor format from the Python side (Rust pins it too)."""

import numpy as np
import pytest

from compile.npk import MAGIC, read_npk, write_npk


def test_roundtrip(tmp_path):
    arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4) * 0.5
    p = tmp_path / "t.npk"
    write_npk(p, arr)
    got = read_npk(p)
    assert got.shape == (2, 3, 4)
    np.testing.assert_array_equal(got, arr)


def test_scalar_and_1d(tmp_path):
    p = tmp_path / "v.npk"
    write_npk(p, np.asarray([1.5, -2.0], np.float32))
    np.testing.assert_array_equal(read_npk(p), [1.5, -2.0])


def test_exact_byte_layout(tmp_path):
    p = tmp_path / "b.npk"
    write_npk(p, np.asarray([[1.0]], np.float32))
    raw = p.read_bytes()
    assert raw[:4] == MAGIC
    assert raw[4:8] == (2).to_bytes(4, "little")
    assert raw[8:12] == (1).to_bytes(4, "little")
    assert raw[12:16] == (1).to_bytes(4, "little")
    assert raw[16:20] == np.float32(1.0).tobytes()
    assert len(raw) == 20


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "x.npk"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        read_npk(p)


def test_truncated_rejected(tmp_path):
    p = tmp_path / "t.npk"
    write_npk(p, np.ones(10, np.float32))
    raw = p.read_bytes()
    p.write_bytes(raw[:-4])
    with pytest.raises(ValueError):
        read_npk(p)
