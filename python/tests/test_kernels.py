"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes; fixed-seed numpy drives the values. This is the
primary correctness signal for the kernel layer — everything downstream
(the lowered artifacts, the Rust runtime) composes these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import fused_linear, matmul
from compile.kernels.gru_cell import gru_cell

DIMS = st.sampled_from([1, 2, 3, 4, 5, 8, 16, 24, 32, 64, 128])
SMALL = st.sampled_from([1, 2, 3, 4, 8, 16])
ACTS = st.sampled_from(["none", "tanh", "relu"])


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(b=SMALL, k=DIMS, n=DIMS, act=ACTS, seed=st.integers(0, 2**16))
def test_fused_linear_matches_ref(b, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, bias = _rand(rng, b, k), _rand(rng, k, n), _rand(rng, n)
    got = fused_linear(x, w, bias, act)
    want = ref.linear_ref(x, w, bias, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=SMALL, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(b=SMALL, d=DIMS, h=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
       seed=st.integers(0, 2**16))
def test_gru_cell_matches_ref(b, d, h, seed):
    rng = np.random.default_rng(seed)
    x, h0 = _rand(rng, b, d), _rand(rng, b, h)
    wx, wh = _rand(rng, d, 3 * h) * 0.3, _rand(rng, h, 3 * h) * 0.3
    bx, bh = _rand(rng, 3 * h) * 0.1, _rand(rng, 3 * h) * 0.1
    got = gru_cell(x, h0, wx, wh, bx, bh)
    want = ref.gru_cell_ref(x, h0, wx, wh, bx, bh)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_linear_under_jit():
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, 4, 8), _rand(rng, 8, 16), _rand(rng, 16)
    got = jax.jit(lambda *a: fused_linear(*a, "tanh"))(x, w, b)
    np.testing.assert_allclose(got, ref.linear_ref(x, w, b, "tanh"), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["none", "tanh", "relu"])
def test_fused_linear_grad_matches_jnp(act):
    """custom_vjp backward (Pallas matmuls) vs jax autodiff of the oracle."""
    rng = np.random.default_rng(1)
    x, w, b = _rand(rng, 4, 8), _rand(rng, 8, 16), _rand(rng, 16)

    def f_ker(x, w, b):
        return jnp.sum(jnp.sin(fused_linear(x, w, b, act)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.linear_ref(x, w, b, act)))

    g_ker = jax.grad(f_ker, argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for gk, gr in zip(g_ker, g_ref):
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


def test_gru_cell_grad_matches_jnp():
    rng = np.random.default_rng(2)
    b_, d, h = 3, 6, 5
    args = (
        _rand(rng, b_, d), _rand(rng, b_, h),
        _rand(rng, d, 3 * h) * 0.3, _rand(rng, h, 3 * h) * 0.3,
        _rand(rng, 3 * h) * 0.1, _rand(rng, 3 * h) * 0.1,
    )

    def f_ker(*a):
        return jnp.sum(jnp.cos(gru_cell(*a)))

    def f_ref(*a):
        return jnp.sum(jnp.cos(ref.gru_cell_ref(*a)))

    g_ker = jax.grad(f_ker, argnums=tuple(range(6)))(*args)
    g_ref = jax.grad(f_ref, argnums=tuple(range(6)))(*args)
    for gk, gr in zip(g_ker, g_ref):
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


def test_gru_saturation_extremes():
    """Gates saturate cleanly: huge positive z ⇒ h' ≈ h."""
    b_, d, h = 2, 3, 4
    x = np.zeros((b_, d), np.float32)
    h0 = np.full((b_, h), 0.7, np.float32)
    wx = np.zeros((d, 3 * h), np.float32)
    wh = np.zeros((h, 3 * h), np.float32)
    bx = np.zeros(3 * h, np.float32)
    bx[h : 2 * h] = 50.0  # z -> 1
    bh = np.zeros(3 * h, np.float32)
    out = gru_cell(x, h0, wx, wh, bx, bh)
    np.testing.assert_allclose(out, h0, atol=1e-6)
