"""AOT path tests: domain configs, lowering, meta contract, goldens."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, envspec as es, model as M
from compile.npk import read_npk

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_domain_cfgs_small_vs_paper():
    small = {c.name: c for c in aot.domain_cfgs("small")}
    paper = {c.name: c for c in aot.domain_cfgs("paper")}
    assert set(small) == {"traffic", "warehouse"}
    # Interface dims must be identical across size presets...
    for d in small:
        assert small[d].policy.obs == paper[d].policy.obs
        assert small[d].policy.act == paper[d].policy.act
        assert small[d].aip.feat == paper[d].aip.feat
        assert small[d].u_dim == paper[d].u_dim
    # ...only capacity changes.
    assert paper["traffic"].policy.h1 > small["traffic"].policy.h1
    assert paper["warehouse"].policy.h2 > small["warehouse"].policy.h2


def test_envspec_consistency():
    assert es.TRAFFIC_OBS == 27
    assert es.TRAFFIC_AIP_FEAT == es.TRAFFIC_OBS + es.TRAFFIC_ACT
    assert es.WAREHOUSE_OBS == 37
    assert es.WAREHOUSE_U_DIM == es.WAREHOUSE_N_HEADS * es.WAREHOUSE_N_CLS


def test_hlo_text_lowering_roundtrips():
    """A tiny fn lowers to parseable HLO text with the tuple-return shape."""
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def _meta(self, domain):
        meta = {}
        with open(os.path.join(ART, f"{domain}.meta")) as f:
            for line in f:
                k, v = line.strip().split("=")
                meta[k] = v
        return meta

    @pytest.mark.parametrize("domain", ["traffic", "warehouse"])
    def test_meta_matches_envspec(self, domain):
        meta = self._meta(domain)
        if domain == "traffic":
            assert int(meta["obs_dim"]) == es.TRAFFIC_OBS
            assert int(meta["act_dim"]) == es.TRAFFIC_ACT
            assert int(meta["u_dim"]) == es.TRAFFIC_U_DIM
            assert int(meta["policy_recurrent"]) == 0
        else:
            assert int(meta["obs_dim"]) == es.WAREHOUSE_OBS
            assert int(meta["act_dim"]) == es.WAREHOUSE_ACT
            assert int(meta["u_dim"]) == es.WAREHOUSE_U_DIM
            assert int(meta["policy_recurrent"]) == 1

    @pytest.mark.parametrize("domain", ["traffic", "warehouse"])
    def test_init_params_match_meta(self, domain):
        meta = self._meta(domain)
        pol = read_npk(os.path.join(ART, f"{domain}_policy_init.npk"))
        aip = read_npk(os.path.join(ART, f"{domain}_aip_init.npk"))
        assert pol.shape == (int(meta["policy_params"]),)
        assert aip.shape == (int(meta["aip_params"]),)
        assert np.all(np.isfinite(pol)) and np.all(np.isfinite(aip))

    @pytest.mark.parametrize("domain", ["traffic", "warehouse"])
    def test_all_artifacts_present(self, domain):
        for suffix in ["policy_step", "ppo_update", "aip_forward",
                       "aip_update", "aip_eval"]:
            p = os.path.join(ART, f"{domain}_{suffix}.hlo.txt")
            assert os.path.isfile(p), p
            with open(p) as f:
                assert "HloModule" in f.read(200)

    def test_goldens_selfconsistent(self):
        """Replaying a golden input through the jax fn reproduces its output."""
        cfg = [c for c in aot.domain_cfgs("small") if c.name == "traffic"][0]
        key = jax.random.PRNGKey(0)
        kp, _ = jax.random.split(key)
        params = M.init_policy(kp, cfg.policy)
        flat, unravel = M.flatten_params(params)
        step = M.make_policy_step(cfg.policy, unravel)
        gd = os.path.join(ART, "golden", "traffic_policy_step")
        ins = [read_npk(os.path.join(gd, f"in0_{k}.npk")) for k in range(3)]
        packed = step(*[jnp.asarray(a) for a in ins])
        want = read_npk(os.path.join(gd, "out0_0.npk"))
        np.testing.assert_allclose(np.asarray(packed), want, rtol=1e-5, atol=1e-6)
