"""Layer-1 Pallas kernel: fused GRU cell (PyTorch gate convention).

One kernel computes both gate projections and the state blend:

    gx = x @ Wx + bx            gh = h @ Wh + bh        (each [B, 3H])
    r  = sigmoid(gx_r + gh_r)   z = sigmoid(gx_z + gh_z)
    n  = tanh(gx_n + r * gh_n)
    h' = (1 - z) * n + z * h

Fusing the two matmuls with the element-wise gate math keeps the whole cell
in one VMEM round-trip instead of five HBM-bound ops; gate order (r, z, n)
matches ``ref.gru_cell_ref``.

Backward: the cell carries a ``jax.custom_vjp``. The backward pass
recomputes the gates (cheap, memory-light) in pure jnp and routes the four
matmul cotangents through the Pallas ``matmul`` kernel from fused_linear.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import INTERPRET, matmul


def _sigmoid(v):
    return 1.0 / (1.0 + jnp.exp(-v))


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, bx_ref, bh_ref, o_ref, *, hid):
    x = x_ref[...]
    h = h_ref[...]
    gx = jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32) + bx_ref[...]
    gh = jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32) + bh_ref[...]
    r = _sigmoid(gx[:, :hid] + gh[:, :hid])
    z = _sigmoid(gx[:, hid : 2 * hid] + gh[:, hid : 2 * hid])
    n = jnp.tanh(gx[:, 2 * hid :] + r * gh[:, 2 * hid :])
    o_ref[...] = (1.0 - z) * n + z * h


def _gru_pallas(x, h, wx, wh, bx, bh):
    bsz, d = x.shape
    hid = h.shape[1]
    import functools

    return pl.pallas_call(
        functools.partial(_gru_kernel, hid=hid),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((bsz, d), lambda i: (0, 0)),
            pl.BlockSpec((bsz, hid), lambda i: (0, 0)),
            pl.BlockSpec((d, 3 * hid), lambda i: (0, 0)),
            pl.BlockSpec((hid, 3 * hid), lambda i: (0, 0)),
            pl.BlockSpec((1, 3 * hid), lambda i: (0, 0)),
            pl.BlockSpec((1, 3 * hid), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bsz, hid), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hid), jnp.float32),
        interpret=INTERPRET,
    )(x, h, wx, wh, bx.reshape(1, -1), bh.reshape(1, -1))


@jax.custom_vjp
def gru_cell(x, h, wx, wh, bx, bh):
    """h' = GRU(x, h). x:[B,D] h:[B,H] wx:[D,3H] wh:[H,3H] bx,bh:[3H]."""
    return _gru_pallas(x, h, wx, wh, bx, bh)


def _gru_fwd(x, h, wx, wh, bx, bh):
    return _gru_pallas(x, h, wx, wh, bx, bh), (x, h, wx, wh, bx, bh)


def _gru_bwd(res, g):
    x, h, wx, wh, bx, bh = res
    hid = h.shape[1]
    # Recompute gates (recompute-over-store: residuals stay O(B·(D+H))).
    gx = jnp.dot(x, wx) + bx[None, :]
    gh = jnp.dot(h, wh) + bh[None, :]
    pre_r = gx[:, :hid] + gh[:, :hid]
    pre_z = gx[:, hid : 2 * hid] + gh[:, hid : 2 * hid]
    ghn = gh[:, 2 * hid :]
    r = _sigmoid(pre_r)
    z = _sigmoid(pre_z)
    n = jnp.tanh(gx[:, 2 * hid :] + r * ghn)

    dn = g * (1.0 - z)
    dz = g * (h - n)
    dpre_n = dn * (1.0 - n * n)
    dr = dpre_n * ghn
    dpre_r = dr * r * (1.0 - r)
    dpre_z = dz * z * (1.0 - z)

    dgx = jnp.concatenate([dpre_r, dpre_z, dpre_n], axis=1)
    dgh = jnp.concatenate([dpre_r, dpre_z, dpre_n * r], axis=1)

    dx = matmul(dgx, wx.T)
    dwx = matmul(x.T, dgx)
    dh = matmul(dgh, wh.T) + g * z
    dwh = matmul(h.T, dgh)
    dbx = jnp.sum(dgx, axis=0)
    dbh = jnp.sum(dgh, axis=0)
    return dx, dh, dwx, dwh, dbx, dbh


gru_cell.defvjp(_gru_fwd, _gru_bwd)
