"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1).

Every Pallas kernel in this package has a reference implementation here.
`python/tests/test_kernels.py` sweeps shapes/dtypes with hypothesis and
asserts allclose between the kernel (interpret=True) and these functions;
this is the core L1 correctness signal.
"""

import jax.numpy as jnp


def apply_act(y, act: str):
    """Shared activation table (must match kernels.fused_linear)."""
    if act == "none":
        return y
    if act == "tanh":
        return jnp.tanh(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    raise ValueError(f"unknown activation {act!r}")


def linear_ref(x, w, b, act: str = "none"):
    """y = act(x @ w + b); x:[B,K] w:[K,N] b:[N]."""
    return apply_act(jnp.dot(x, w) + b[None, :], act)


def matmul_ref(a, b):
    """c = a @ b; a:[M,K] b:[K,N]."""
    return jnp.dot(a, b)


def gru_cell_ref(x, h, wx, wh, bx, bh):
    """PyTorch-convention GRU cell.

    x:[B,D] h:[B,H] wx:[D,3H] wh:[H,3H] bx,bh:[3H]
    gates ordered (r, z, n) along the 3H axis.
    """
    hid = h.shape[1]
    gx = jnp.dot(x, wx) + bx[None, :]
    gh = jnp.dot(h, wh) + bh[None, :]
    r = 1.0 / (1.0 + jnp.exp(-(gx[:, :hid] + gh[:, :hid])))
    z = 1.0 / (1.0 + jnp.exp(-(gx[:, hid : 2 * hid] + gh[:, hid : 2 * hid])))
    n = jnp.tanh(gx[:, 2 * hid :] + r * gh[:, 2 * hid :])
    return (1.0 - z) * n + z * h
