"""Layer-1 Pallas kernel: fused linear layer  y = act(x @ W + b).

This is the compute hot spot of every network in the system (policy FNNs,
GRU gate projections, AIP heads). The kernel is tiled for TPU execution —
block shapes are chosen as multiples of the (8, 128) VPU/MXU lane layout
whenever the operand dims allow — but is *run* with ``interpret=True``
because the CPU PJRT plugin cannot execute Mosaic custom-calls (see
DESIGN.md §Hardware-Adaptation).

Autodiff: ``pallas_call`` is not differentiable, so the public entry point
``fused_linear`` carries a ``jax.custom_vjp`` whose backward pass is also
expressed with Pallas matmul kernels:

    dx = g' @ W^T      dW = x^T @ g'      db = sum_B g'

where g' folds the activation derivative into the cotangent.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret=True is mandatory on CPU; kept as a module switch so a real-TPU
# build can flip it in one place.
INTERPRET = True

_LANE = 128  # MXU/VPU minor-dim tile
_SUBLANE = 8  # second-minor tile for f32


def _block(dim: int, pref: int) -> int:
    """Largest tile ≤ pref that divides dim (falls back to dim itself)."""
    if dim % pref == 0:
        return pref
    for cand in (pref // 2, pref // 4, pref // 8):
        if cand and dim % cand == 0:
            return cand
    return dim


def _apply_act(y, act: str):
    if act == "none":
        return y
    if act == "tanh":
        return jnp.tanh(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    raise ValueError(f"unknown activation {act!r}")


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    # x tile: [bm, K]  w tile: [K, bn]  b tile: [1, bn]  → o tile: [bm, bn]
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = _apply_act(y + b_ref[...], act)


def _linear_pallas(x, w, b, act: str):
    bsz, k = x.shape
    n = w.shape[1]
    bm = _block(bsz, _SUBLANE)
    bn = _block(n, _LANE)
    grid = (bsz // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_linear_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b.reshape(1, n))


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def matmul(a, b):
    """Pallas tiled matmul c = a @ b (used by the backward pass)."""
    m, k = a.shape
    n = b.shape[1]
    bm = _block(m, _SUBLANE)
    bn = _block(n, _LANE)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act: str = "none"):
    """y = act(x @ w + b) as a single Pallas kernel. x:[B,K] w:[K,N] b:[N]."""
    return _linear_pallas(x, w, b, act)


def _fused_linear_fwd(x, w, b, act):
    y = _linear_pallas(x, w, b, act)
    return y, (x, w, y)


def _fused_linear_bwd(act, res, g):
    x, w, y = res
    if act == "tanh":
        g = g * (1.0 - y * y)
    elif act == "relu":
        g = g * (y > 0.0).astype(g.dtype)
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
