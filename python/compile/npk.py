"""NPK: the tiny tensor interchange format shared with the Rust side.

Layout (little-endian):
    magic   4 bytes  b"NPK1"
    ndim    u32
    dims    ndim × u32
    data    prod(dims) × f32

All tensors in the system are f32; integer payloads (actions, class labels)
are carried as f32 and cast inside the HLO graphs. The Rust reader/writer
lives in ``rust/src/util/npk.rs``; ``python/tests/test_npk.py`` and the Rust
unit tests pin the format from both sides.
"""

import struct

import numpy as np

MAGIC = b"NPK1"


def write_npk(path, arr) -> None:
    arr = np.ascontiguousarray(arr, dtype=np.float32)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            f.write(struct.pack("<I", d))
        f.write(arr.tobytes())


def read_npk(path) -> np.ndarray:
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (ndim,) = struct.unpack("<I", f.read(4))
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
        data = np.frombuffer(f.read(), dtype="<f4")
    n = int(np.prod(dims)) if dims else 1
    if data.size != n:
        raise ValueError(f"{path}: expected {n} elems, got {data.size}")
    return data.reshape(dims).copy()
