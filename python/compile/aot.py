"""AOT compile path: lower every Layer-2 function to HLO text artifacts.

Run once via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Per domain (traffic, warehouse) this emits:

    <dom>_policy_step.hlo.txt   (flat,obs[1,D],h[1,H]) -> packed (B=1)
    <dom>_policy_step_b.hlo.txt (flats[N,P],obs[N,D],h[N,H]) -> packed[N,·]
                                (one call per joint step; N = --batch)
    <dom>_ppo_update.hlo.txt    one PPO minibatch Adam step
    <dom>_ppo_update_b.hlo.txt  fused [N]-wide PPO minibatch step (one call
                                updates all N agents' packed states)
    <dom>_aip_forward.hlo.txt   (flat,feat[1,F],h[1,H]) -> packed (B=1)
    <dom>_aip_forward_b.hlo.txt batched joint-step AIP forward
    <dom>_aip_update.hlo.txt    one AIP cross-entropy Adam step
    <dom>_aip_update_b.hlo.txt  fused [N]-wide AIP cross-entropy step (one
                                call retrains all N agents' packed states)
    <dom>_aip_eval.hlo.txt      batch CE loss (Fig. 4 curves)
    <dom>_policy_init.npk       initial flat policy params
    <dom>_aip_init.npk          initial flat AIP params
    <dom>.meta                  key=value interface contract for Rust
    golden/<artifact>/{in,out}NN.npk   golden IO for Rust integration tests

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import envspec as es
from . import model as M
from .npk import write_npk


# --------------------------------------------------------------------------
# Domain configurations
# --------------------------------------------------------------------------

class DomainCfg:
    """Everything aot needs to lower one domain's artifact set."""

    def __init__(self, name, policy: M.PolicySpec, aip: M.AipSpec,
                 ppo: M.PpoCfg, aip_lr: float, minibatch: int,
                 aip_batch: int, aip_seq: int, u_dim: int):
        self.name = name
        self.policy = policy
        self.aip = aip
        self.ppo = ppo
        self.aip_lr = aip_lr
        self.minibatch = minibatch
        self.aip_batch = aip_batch
        self.aip_seq = aip_seq
        self.u_dim = u_dim


def domain_cfgs(size: str):
    """`small` (default; CPU-friendly) or `paper` (Table 4/5 sizes)."""
    if size == "paper":
        t_pol, w_emb, w_hid = (256, 128), 256, 128
        t_aip, w_aip, w_seq = 128, 64, 100
    else:
        t_pol, w_emb, w_hid = (64, 64), 64, 64
        t_aip, w_aip, w_seq = 64, 32, 16
    traffic = DomainCfg(
        "traffic",
        policy=M.PolicySpec(es.TRAFFIC_OBS, es.TRAFFIC_ACT, False, *t_pol),
        aip=M.AipSpec(es.TRAFFIC_AIP_FEAT, False, t_aip, es.TRAFFIC_N_SRC, 1),
        ppo=M.PpoCfg(),
        aip_lr=1e-4,
        minibatch=32,
        aip_batch=128,
        aip_seq=1,
        u_dim=es.TRAFFIC_U_DIM,
    )
    warehouse = DomainCfg(
        "warehouse",
        policy=M.PolicySpec(es.WAREHOUSE_OBS, es.WAREHOUSE_ACT, True, w_emb, w_hid),
        aip=M.AipSpec(es.WAREHOUSE_AIP_FEAT, True, w_aip,
                      es.WAREHOUSE_N_HEADS, es.WAREHOUSE_N_CLS),
        ppo=M.PpoCfg(),
        aip_lr=1e-4,
        minibatch=32,
        aip_batch=32,
        aip_seq=w_seq,
        u_dim=es.WAREHOUSE_U_DIM,
    )
    return [traffic, warehouse]


# --------------------------------------------------------------------------
# Lowering helpers
# --------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    # return_tuple=False: PJRT untuples the root into one device buffer per
    # output, which lets the Rust side chain update outputs (params, m, v)
    # directly into the next execute_b call without host round-trips.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def lower_and_write(fn, args, out_path):
    # keep_unused=True: the unified signatures carry dummy hidden-state
    # args for the FNN variants; default jit would DCE them out of the
    # compiled HLO and break the Rust caller's calling convention.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return lowered


def write_golden(fn, arg_specs, gold_dir, seed, n_cases=2, label_heads=None,
                 label_cls=0, arg_kinds=None):
    """Run `fn` on deterministic random inputs; dump input/output NPKs.

    arg_kinds: optional {arg_index: kind} map with semantic constraints —
      "nonneg" (Adam second moment: |x|), "step" (Adam step counter: 1.0),
      "tfirst" (packed batch whose element 0 is the step counter),
      "tfirst_rows" (stacked packed batches: element 0 of EVERY row is a
      step counter).
    """
    os.makedirs(gold_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    jfn = jax.jit(fn, keep_unused=True)
    arg_kinds = arg_kinds or {}
    for c in range(n_cases):
        ins = []
        for k, spec in enumerate(arg_specs):
            if label_heads is not None and k == len(arg_specs) - 1:
                # Final arg is a label tensor: integer classes as f32.
                a = rng.integers(0, max(label_cls, 2), size=spec.shape)
                a = a.astype(np.float32)
                if label_cls == 0:  # Bernoulli labels
                    a = (a > 0).astype(np.float32)
            else:
                a = rng.standard_normal(spec.shape).astype(np.float32) * 0.5
                kind = arg_kinds.get(k)
                if kind == "nonneg":
                    a = np.abs(a)
                elif kind == "step":
                    a = np.ones(spec.shape, np.float32)
                elif kind == "tfirst":
                    a.flat[0] = 1.0
                elif kind == "tfirst_rows":
                    a[..., 0] = 1.0
            ins.append(a)
        outs = jfn(*ins)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for o in outs:
            assert np.all(np.isfinite(np.asarray(o))), f"golden output not finite in {gold_dir}"
        for k, a in enumerate(ins):
            write_npk(os.path.join(gold_dir, f"in{c}_{k}.npk"), a)
        for k, o in enumerate(outs):
            write_npk(os.path.join(gold_dir, f"out{c}_{k}.npk"), np.asarray(o))


# --------------------------------------------------------------------------
# Per-domain emission
# --------------------------------------------------------------------------

def emit_domain(cfg: DomainCfg, out_dir: str, seed: int, goldens: bool, batch: int,
                replicas: int = 1):
    key = jax.random.PRNGKey(seed)
    kp, ka = jax.random.split(key)
    pol_params = M.init_policy(kp, cfg.policy)
    aip_params = M.init_aip(ka, cfg.aip)
    pol_flat, pol_unravel = M.flatten_params(pol_params)
    aip_flat, aip_unravel = M.flatten_params(aip_params)

    d = cfg.name
    ps, asp = cfg.policy, cfg.aip
    mb = cfg.minibatch

    write_npk(os.path.join(out_dir, f"{d}_policy_init.npk"), np.asarray(pol_flat))
    write_npk(os.path.join(out_dir, f"{d}_aip_init.npk"), np.asarray(aip_flat))

    pdim, adim = pol_flat.shape[0], aip_flat.shape[0]

    # ---- policy step (B=1 streaming; drives the per-agent LS segments)
    policy_step = M.make_policy_step(ps, pol_unravel)
    step_args = (_spec(pdim), _spec(1, ps.obs), _spec(1, ps.hstate))
    lower_and_write(policy_step, step_args, os.path.join(out_dir, f"{d}_policy_step.hlo.txt"))

    # ---- batched joint step (one call forwards all `batch` agents, each
    # with its own parameter row — the runtime::batch bank path)
    # `replicas` > 1 lowers the megabatch shape: [batch*R] data rows over
    # [batch] parameter rows (replica->agent indirection in-graph).
    rows = batch * replicas
    policy_step_b = M.make_policy_step_batched(ps, pol_unravel, replicas)
    step_b_args = (_spec(batch, pdim), _spec(rows, ps.obs), _spec(rows, ps.hstate))
    lower_and_write(policy_step_b, step_b_args,
                    os.path.join(out_dir, f"{d}_policy_step_b.hlo.txt"))

    # ---- PPO minibatch update (packed state + packed batch)
    ppo_update = M.make_ppo_update(ps, cfg.ppo, pol_unravel, pdim, mb)
    upd_args = (
        _spec(3 * pdim + 4),
        _spec(1 + mb * (ps.obs + ps.hstate + 4)),
    )
    lower_and_write(ppo_update, upd_args, os.path.join(out_dir, f"{d}_ppo_update.hlo.txt"))

    # ---- fused [N]-wide PPO update: one call per minibatch step updates
    # every agent's packed state against its own [N]-row staging tensor
    # (the Rust TrainBank / update_fused path).
    ppo_update_b = M.make_ppo_update_b(ps, cfg.ppo, pol_unravel, pdim, mb)
    upd_b_args = (
        _spec(batch, 3 * pdim + 4),
        _spec(batch, 1 + mb * (ps.obs + ps.hstate + 4)),
    )
    lower_and_write(ppo_update_b, upd_b_args,
                    os.path.join(out_dir, f"{d}_ppo_update_b.hlo.txt"))

    # ---- AIP forward (B=1 streaming + batched joint step)
    aip_forward = M.make_aip_forward(asp, aip_unravel)
    af_args = (_spec(adim), _spec(1, asp.feat), _spec(1, asp.hstate))
    lower_and_write(aip_forward, af_args, os.path.join(out_dir, f"{d}_aip_forward.hlo.txt"))

    aip_forward_b = M.make_aip_forward_batched(asp, aip_unravel, replicas)
    af_b_args = (_spec(batch, adim), _spec(rows, asp.feat), _spec(rows, asp.hstate))
    lower_and_write(aip_forward_b, af_b_args,
                    os.path.join(out_dir, f"{d}_aip_forward_b.hlo.txt"))

    # ---- AIP update + eval (packed state + packed batch)
    adam = M.AdamCfg(lr=cfg.aip_lr)
    if asp.recurrent:
        fshape = (cfg.aip_batch, cfg.aip_seq, asp.feat)
        lshape = (cfg.aip_batch, cfg.aip_seq, asp.n_heads)
    else:
        fshape = (cfg.aip_batch, asp.feat)
        lshape = (cfg.aip_batch, asp.n_heads)
    feats = _spec(*fshape)
    labels = _spec(*lshape)
    aip_update = M.make_aip_update(asp, adam, aip_unravel, adim, fshape, lshape)
    aip_eval = M.make_aip_eval(asp, aip_unravel)
    import numpy as _np
    au_args = (
        _spec(3 * adim + 1),
        _spec(1 + int(_np.prod(fshape)) + int(_np.prod(lshape))),
    )
    lower_and_write(aip_update, au_args, os.path.join(out_dir, f"{d}_aip_update.hlo.txt"))
    lower_and_write(aip_eval, (_spec(adim), feats, labels),
                    os.path.join(out_dir, f"{d}_aip_eval.hlo.txt"))

    # ---- fused [N]-wide AIP update: one call per retrain epoch updates
    # every agent's packed AIP state against its own sampled batch row
    # (the Rust influence::train_aip_fused path).
    aip_update_b = M.make_aip_update_b(asp, adam, aip_unravel, adim, fshape, lshape)
    au_b_args = (
        _spec(batch, 3 * adim + 1),
        _spec(batch, 1 + int(_np.prod(fshape)) + int(_np.prod(lshape))),
    )
    lower_and_write(aip_update_b, au_b_args,
                    os.path.join(out_dir, f"{d}_aip_update_b.hlo.txt"))

    # ---- interface contract for the Rust loader
    meta = {
        "domain": d,
        "obs_dim": ps.obs,
        "act_dim": ps.act,
        "policy_recurrent": int(ps.recurrent),
        "policy_hstate": ps.hstate,
        "policy_params": pdim,
        "aip_feat": asp.feat,
        "aip_recurrent": int(asp.recurrent),
        "aip_hstate": asp.hstate,
        "aip_params": adim,
        "aip_heads": asp.n_heads,
        "aip_cls": asp.n_cls,
        "u_dim": cfg.u_dim,
        "minibatch": mb,
        "aip_batch": cfg.aip_batch,
        "aip_seq": cfg.aip_seq,
        "seed": seed,
        # batch-first keys: layer widths let the Rust native backend
        # execute the forward families directly (runtime::layout), and
        # `batch` records the N the `_b` artifacts were lowered for.
        "policy_h1": ps.h1,
        "policy_h2": ps.h2,
        "aip_hid": asp.hid,
        "batch": batch,
        # replica rows per agent the `_b` artifacts were lowered for (the
        # megabatch LS-training shape; 1 = plain joint step).
        "replicas": replicas,
        # PPO hyperparameters baked into the update graphs — the native
        # backward kernels (runtime::layout) bind these so the default
        # no-XLA build trains with the same pinned Table-6 values.
        "clip_eps": cfg.ppo.clip_eps,
        "vf_coef": cfg.ppo.vf_coef,
        "ent_coef": cfg.ppo.ent_coef,
        "max_grad_norm": cfg.ppo.max_grad_norm,
        "lr": cfg.ppo.adam.lr,
        "adam_b1": cfg.ppo.adam.b1,
        "adam_b2": cfg.ppo.adam.b2,
        "adam_eps": cfg.ppo.adam.eps,
        # AIP retrain hyperparameters (Table 4) — baked into the
        # aip_update graphs and bound by the native CE backward kernels.
        "aip_lr": adam.lr,
        "aip_adam_b1": adam.b1,
        "aip_adam_b2": adam.b2,
        "aip_adam_eps": adam.eps,
    }
    with open(os.path.join(out_dir, f"{d}.meta"), "w") as f:
        for k, v in meta.items():
            f.write(f"{k}={v}\n")

    # ---- golden IO for the Rust runtime integration tests
    if goldens:
        gd = os.path.join(out_dir, "golden")
        write_golden(policy_step, step_args, os.path.join(gd, f"{d}_policy_step"), seed + 1)
        write_golden(aip_forward, af_args, os.path.join(gd, f"{d}_aip_forward"), seed + 2)
        write_golden(policy_step_b, step_b_args,
                     os.path.join(gd, f"{d}_policy_step_b"), seed + 1, n_cases=1)
        write_golden(aip_forward_b, af_b_args,
                     os.path.join(gd, f"{d}_aip_forward_b"), seed + 2, n_cases=1)
        # packed state arg 0 must be non-negative (its v-slice feeds sqrt);
        # packed batch arg 1 carries the step counter at element 0.
        adam_kinds = {0: "nonneg", 1: "tfirst"}
        write_golden(
            ppo_update, upd_args, os.path.join(gd, f"{d}_ppo_update"), seed + 3,
            n_cases=1, arg_kinds=adam_kinds,
        )
        write_golden(
            ppo_update_b, upd_b_args, os.path.join(gd, f"{d}_ppo_update_b"), seed + 3,
            n_cases=1, arg_kinds={0: "nonneg", 1: "tfirst_rows"},
        )
        write_golden(
            aip_update, au_args, os.path.join(gd, f"{d}_aip_update"), seed + 4,
            n_cases=1, arg_kinds=adam_kinds,
        )
        write_golden(
            aip_update_b, au_b_args, os.path.join(gd, f"{d}_aip_update_b"), seed + 4,
            n_cases=1, arg_kinds={0: "nonneg", 1: "tfirst_rows"},
        )
    print(f"[aot] {d}: policy_params={pdim} aip_params={adim}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--size", choices=["small", "paper"], default="small")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--domains", default="traffic,warehouse")
    ap.add_argument("--no-goldens", action="store_true")
    ap.add_argument("--batch", type=int, default=25,
                    help="agent count N the batched `_b` artifacts are lowered "
                         "for (= grid_side^2 of the runs you plan; HLO is "
                         "shape-specialised)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="LS replicas R per agent the `_b` artifacts are "
                         "lowered for (megabatch training: [N*R] data rows "
                         "over N parameter rows; 1 = plain joint step)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = set(args.domains.split(","))
    for cfg in domain_cfgs(args.size):
        if cfg.name in wanted:
            emit_domain(cfg, args.out_dir, args.seed, not args.no_goldens, args.batch,
                        args.replicas)
    print(f"[aot] artifacts written to {args.out_dir}")


if __name__ == "__main__":
    main()
