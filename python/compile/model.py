"""Layer-2: JAX definitions of every network and training update in DIALS.

Contents
--------
* Policy networks: FNN (traffic, paper Table 5) and GRU (warehouse), both
  exposed through the unified signature
      policy_step(flat_params, obs[B,D], h[B,H]) -> (logits[B,A], value[B], h'[B,H])
  (the FNN carries a width-1 dummy hidden state so the Rust driver is
  domain-agnostic).
* Approximate Influence Predictors (AIPs, paper §3.2 / App. E.1): FNN with
  Bernoulli heads (traffic) and GRU with categorical heads (warehouse),
  unified as
      aip_forward(flat_params, feat[B,F], h[B,H]) -> (probs[B,U], h'[B,H])
* PPO clipped-surrogate minibatch update with Adam folded into the graph
  (paper Table 6 hyperparameters), and AIP cross-entropy updates (Table 4).

All parameters travel as a single flat f32 vector (ravel_pytree) so the
Rust side only ever holds opaque buffers; aot.py lowers each function once
per domain to an HLO-text artifact.

Every dense projection and GRU cell routes through the Layer-1 Pallas
kernels (`kernels.fused_linear`, `kernels.gru_cell`).
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.fused_linear import fused_linear
from .kernels.gru_cell import gru_cell


# --------------------------------------------------------------------------
# Initialisers
# --------------------------------------------------------------------------

def _dense_init(key, fan_in, fan_out, scale=None):
    """Orthogonal-ish (scaled Gaussian) init, zeros bias."""
    if scale is None:
        scale = (2.0 / (fan_in + fan_out)) ** 0.5
    w = scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)
    return {"w": w, "b": jnp.zeros((fan_out,), jnp.float32)}


def _gru_init(key, feat, hid):
    k1, k2 = jax.random.split(key)
    s_x = (1.0 / feat) ** 0.5
    s_h = (1.0 / hid) ** 0.5
    return {
        "wx": s_x * jax.random.normal(k1, (feat, 3 * hid), jnp.float32),
        "wh": s_h * jax.random.normal(k2, (hid, 3 * hid), jnp.float32),
        "bx": jnp.zeros((3 * hid,), jnp.float32),
        "bh": jnp.zeros((3 * hid,), jnp.float32),
    }


def _dense(p, x, act="none"):
    return fused_linear(x, p["w"], p["b"], act)


# --------------------------------------------------------------------------
# Policy networks
# --------------------------------------------------------------------------

class PolicySpec(NamedTuple):
    obs: int
    act: int
    recurrent: bool
    h1: int  # embed size (recurrent) or first hidden (FNN)
    h2: int  # GRU hidden (recurrent) or second hidden (FNN)

    @property
    def hstate(self) -> int:
        return self.h2 if self.recurrent else 1


def init_policy(key, spec: PolicySpec):
    ks = jax.random.split(key, 4)
    if spec.recurrent:
        return {
            "emb": _dense_init(ks[0], spec.obs, spec.h1),
            "gru": _gru_init(ks[1], spec.h1, spec.h2),
            "pi": _dense_init(ks[2], spec.h2, spec.act, scale=0.01),
            "vf": _dense_init(ks[3], spec.h2, 1, scale=1.0),
        }
    return {
        "fc1": _dense_init(ks[0], spec.obs, spec.h1),
        "fc2": _dense_init(ks[1], spec.h1, spec.h2),
        "pi": _dense_init(ks[2], spec.h2, spec.act, scale=0.01),
        "vf": _dense_init(ks[3], spec.h2, 1, scale=1.0),
    }


def policy_apply(params, spec: PolicySpec, obs, h):
    """Shared forward. obs:[B,D] h:[B,H] -> (logits, value[B], h')."""
    if spec.recurrent:
        e = _dense(params["emb"], obs, "tanh")
        g = params["gru"]
        h_new = gru_cell(e, h, g["wx"], g["wh"], g["bx"], g["bh"])
        z = h_new
    else:
        z = _dense(params["fc2"], _dense(params["fc1"], obs, "tanh"), "tanh")
        h_new = jnp.zeros_like(h)
    logits = _dense(params["pi"], z)
    value = _dense(params["vf"], z)[:, 0]
    return logits, value, h_new


def make_policy_step(spec: PolicySpec, unravel):
    """B=1 streaming step, packed output.

    All artifacts return a SINGLE array: the vendored xla runtime returns
    multi-output programs as one tuple buffer that cannot be re-fed to
    `execute_b`, so outputs are concatenated and sliced by the Rust caller.

    (flat[P], obs[1,D], h[1,H]) -> packed[A + 1 + H] =
        [logits | value | h']
    """

    def step(flat, obs, h):
        logits, value, h_new = policy_apply(unravel(flat), spec, obs, h)
        return jnp.concatenate([logits[0], value, h_new[0]])

    return step


def make_policy_step_batched(spec: PolicySpec, unravel, replicas: int = 1):
    """Joint-step variant: every agent has its OWN parameter row, so the
    whole coordinator-side joint step is ONE executable call (the Rust
    `runtime::batch::PolicyBank` drives this; one `run_b` instead of N).

    vmap of the B=1 row over the stacked agents — per-row numerics are
    identical to `make_policy_step` by construction.

    (flats[N,P], obs[N,D], h[N,H]) -> packed[N, A + 1 + H]

    With `replicas = R > 1` (the megabatch LS-training path) the data rows
    carry R replicas per agent, agent-major, while the parameter stack
    stays [N, P]: the replica->agent row indirection is an in-graph
    `jnp.repeat` (row i reads param row i // R), so parameters are never
    duplicated host-side.

    (flats[N,P], obs[N*R,D], h[N*R,H]) -> packed[N*R, A + 1 + H]
    """

    def row(flat, obs, h):
        logits, value, h_new = policy_apply(unravel(flat), spec, obs[None, :], h[None, :])
        return jnp.concatenate([logits[0], value, h_new[0]])

    def step(flats, obs, h):
        if replicas > 1:
            flats = jnp.repeat(flats, replicas, axis=0)
        return jax.vmap(row)(flats, obs, h)

    return step


# --------------------------------------------------------------------------
# AIP networks
# --------------------------------------------------------------------------

class AipSpec(NamedTuple):
    feat: int
    recurrent: bool
    hid: int
    n_heads: int  # number of influence sources
    n_cls: int  # 1 => Bernoulli head (sigmoid); >1 => softmax head

    @property
    def u_dim(self) -> int:
        return self.n_heads * self.n_cls

    @property
    def hstate(self) -> int:
        return self.hid if self.recurrent else 1


def init_aip(key, spec: AipSpec):
    ks = jax.random.split(key, 3)
    out = spec.n_heads * max(spec.n_cls, 1)
    if spec.recurrent:
        return {
            "gru": _gru_init(ks[0], spec.feat, spec.hid),
            "head": _dense_init(ks[1], spec.hid, out),
        }
    return {
        "fc1": _dense_init(ks[0], spec.feat, spec.hid),
        "fc2": _dense_init(ks[1], spec.hid, spec.hid),
        "head": _dense_init(ks[2], spec.hid, out),
    }


def _aip_logits(params, spec: AipSpec, feat, h):
    if spec.recurrent:
        g = params["gru"]
        h_new = gru_cell(feat, h, g["wx"], g["wh"], g["bx"], g["bh"])
        z = h_new
    else:
        z = _dense(params["fc2"], _dense(params["fc1"], feat, "tanh"), "tanh")
        h_new = jnp.zeros_like(h)
    return _dense(params["head"], z), h_new


def aip_apply(params, spec: AipSpec, feat, h):
    """feat:[B,F] h:[B,H] -> (probs[B,U], h').

    Bernoulli heads (n_cls == 1): probs[:, k] = P(u_k = 1).
    Categorical heads: probs reshaped per head and softmaxed.
    """
    logits, h_new = _aip_logits(params, spec, feat, h)
    if spec.n_cls == 1:
        probs = jax.nn.sigmoid(logits)
    else:
        b = feat.shape[0]
        grouped = logits.reshape(b, spec.n_heads, spec.n_cls)
        probs = jax.nn.softmax(grouped, axis=-1).reshape(b, spec.u_dim)
    return probs, h_new


def make_aip_forward(spec: AipSpec, unravel):
    """B=1 streaming forward, packed output (see make_policy_step):

    (flat[P], feat[1,F], h[1,H]) -> packed[U + H] = [probs | h']
    """

    def fwd(flat, feat, h):
        probs, h_new = aip_apply(unravel(flat), spec, feat, h)
        return jnp.concatenate([probs[0], h_new[0]])

    return fwd


def make_aip_forward_batched(spec: AipSpec, unravel, replicas: int = 1):
    """Joint-step AIP variant (see make_policy_step_batched; `replicas`
    adds the same agent-major R-replica row indirection):

    (flats[N,P], feats[N*R,F], h[N*R,H]) -> packed[N*R, U + H]
    """

    def row(flat, feat, h):
        probs, h_new = aip_apply(unravel(flat), spec, feat[None, :], h[None, :])
        return jnp.concatenate([probs[0], h_new[0]])

    def fwd(flats, feats, h):
        if replicas > 1:
            flats = jnp.repeat(flats, replicas, axis=0)
        return jax.vmap(row)(flats, feats, h)

    return fwd


def aip_ce_loss(params, spec: AipSpec, feats, labels):
    """Mean cross-entropy of the AIP on a batch.

    FNN AIP: feats:[B,F], labels:[B,n_heads] in {0,1}.
    GRU AIP: feats:[B,T,F], labels:[B,T,n_heads] class indices (as f32);
             the GRU is unrolled over T from h0 = 0 (BPTT over the whole
             sequence, paper App. I "seq. length").
    """
    if spec.recurrent:
        b, t, _ = feats.shape
        h0 = jnp.zeros((b, spec.hid), jnp.float32)

        def scan_fn(h, xt):
            logits, h = _aip_logits(params, spec, xt, h)
            return h, logits

        _, logits_t = jax.lax.scan(scan_fn, h0, jnp.swapaxes(feats, 0, 1))
        logits = jnp.swapaxes(logits_t, 0, 1)  # [B,T,out]
        grouped = logits.reshape(b, t, spec.n_heads, spec.n_cls)
        logp = jax.nn.log_softmax(grouped, axis=-1)
        idx = labels.astype(jnp.int32)  # [B,T,n_heads]
        picked = jnp.take_along_axis(logp, idx[..., None], axis=-1)[..., 0]
        return -jnp.mean(picked)
    h0 = jnp.zeros((feats.shape[0], 1), jnp.float32)
    logits, _ = _aip_logits(params, spec, feats, h0)
    # Numerically-stable BCE with logits.
    y = labels
    ce = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return jnp.mean(ce)


# --------------------------------------------------------------------------
# Adam (folded into the update graphs)
# --------------------------------------------------------------------------

class AdamCfg(NamedTuple):
    lr: float = 2.5e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-5


def adam_step(flat, m, v, g, t, cfg: AdamCfg):
    """One Adam step on flat vectors. t: f32[1] 1-based step counter."""
    m = cfg.b1 * m + (1.0 - cfg.b1) * g
    v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
    t1 = t[0]
    mhat = m / (1.0 - cfg.b1 ** t1)
    vhat = v / (1.0 - cfg.b2 ** t1)
    flat = flat - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
    return flat, m, v


# --------------------------------------------------------------------------
# PPO update (paper Table 6)
# --------------------------------------------------------------------------

class PpoCfg(NamedTuple):
    clip_eps: float = 0.1
    vf_coef: float = 1.0
    ent_coef: float = 1.0e-2
    adam: AdamCfg = AdamCfg(lr=2.5e-4)
    max_grad_norm: float = 0.5


def ppo_loss(params, spec: PolicySpec, cfg: PpoCfg, obs, h0, act, old_logp, adv, ret):
    logits, value, _ = policy_apply(params, spec, obs, h0)
    logp_all = jax.nn.log_softmax(logits)
    a = act.astype(jnp.int32)
    logp = jnp.take_along_axis(logp_all, a[:, None], axis=1)[:, 0]
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps)
    pg_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    v_loss = jnp.mean((value - ret) ** 2)
    probs = jax.nn.softmax(logits)
    entropy = -jnp.mean(jnp.sum(probs * logp_all, axis=1))
    total = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
    return total, (pg_loss, v_loss, entropy)


def _clip_by_global_norm(g, max_norm):
    norm = jnp.sqrt(jnp.sum(g * g))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-8))
    return g * scale


def make_ppo_update(spec: PolicySpec, cfg: PpoCfg, unravel, pdim: int, mb: int):
    """One minibatch gradient step; epochs × minibatches loop lives in Rust.

    Packed-state convention (single-output, chainable through execute_b):

    (state[3P+4], batch[1 + MB*(D+H+4)]) -> state'[3P+4]
      state  = [flat | m | v | tail(ignored)]
      batch  = [t | obs(MB·D) | h0(MB·H) | act(MB) | old_logp(MB)
                  | adv(MB) | ret(MB)]      (single upload per minibatch)
      state' = [flat'| m'| v'| metrics(total, pg, vf, entropy)]
    """
    d, h = spec.obs, spec.hstate

    def update(state, batch):
        flat = state[:pdim]
        m = state[pdim : 2 * pdim]
        v = state[2 * pdim : 3 * pdim]
        t = batch[:1]
        o = 1
        obs = batch[o : o + mb * d].reshape(mb, d)
        o += mb * d
        h0 = batch[o : o + mb * h].reshape(mb, h)
        o += mb * h
        act = batch[o : o + mb]
        old_logp = batch[o + mb : o + 2 * mb]
        adv = batch[o + 2 * mb : o + 3 * mb]
        ret = batch[o + 3 * mb : o + 4 * mb]

        def loss_fn(fl):
            return ppo_loss(
                unravel(fl), spec, cfg, obs, h0, act, old_logp, adv, ret
            )

        (total, (pg, vl, ent)), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        g = _clip_by_global_norm(g, cfg.max_grad_norm)
        flat, m, v = adam_step(flat, m, v, g, t, cfg.adam)
        metrics = jnp.stack([total, pg, vl, ent])
        return jnp.concatenate([flat, m, v, metrics])

    return update


def make_ppo_update_b(spec: PolicySpec, cfg: PpoCfg, unravel, pdim: int, mb: int):
    """Fused [N]-wide PPO minibatch step: vmap of `make_ppo_update`'s row
    over all N agents' stacked packed states, so every minibatch step of
    the whole system is ONE executable call (the Rust
    `runtime::batch::TrainBank` / `PpoTrainer::update_fused` path; the
    per-agent minibatch loop still lives in Rust). The vmapped program is
    the B=1 row per agent, but XLA batches the matmuls, so lowered
    numerics match the per-agent executable to f32-reassociation
    tolerance rather than bitwise (the native backend's row loop is the
    bit-identical one, pinned by `tests/native_training.rs`).

    (states[N, 3P+4], batches[N, 1 + MB*(D+H+4)]) -> states'[N, 3P+4]
    """
    row = make_ppo_update(spec, cfg, unravel, pdim, mb)

    def update(states, batches):
        return jax.vmap(row)(states, batches)

    return update


def make_aip_update(spec: AipSpec, adam_cfg: AdamCfg, unravel, adim: int,
                    batch_shape, label_shape):
    """Packed-state AIP update (see make_ppo_update):

    (state[3P+1], batch[1 + prod(feats) + prod(labels)]) -> state'[3P+1]
      batch  = [t | feats | labels]     (single upload per gradient step)
      state' = [flat' | m' | v' | ce]
    """
    import numpy as _np

    f_n = int(_np.prod(batch_shape))
    l_n = int(_np.prod(label_shape))

    def update(state, batch):
        flat = state[:adim]
        m = state[adim : 2 * adim]
        v = state[2 * adim : 3 * adim]
        t = batch[:1]
        feats = batch[1 : 1 + f_n].reshape(batch_shape)
        labels = batch[1 + f_n : 1 + f_n + l_n].reshape(label_shape)

        def loss_fn(fl):
            return aip_ce_loss(unravel(fl), spec, feats, labels)

        ce, g = jax.value_and_grad(loss_fn)(flat)
        flat, m, v = adam_step(flat, m, v, g, t, adam_cfg)
        return jnp.concatenate([flat, m, v, ce.reshape(1)])

    return update


def make_aip_update_b(spec: AipSpec, adam_cfg: AdamCfg, unravel, adim: int,
                      batch_shape, label_shape):
    """Fused [N]-wide AIP cross-entropy step: vmap of `make_aip_update`'s
    row over all N agents' stacked packed states, so every retrain epoch
    of the whole system is ONE executable call (the Rust
    `influence::train_aip_fused` path; the epoch loop and batch sampling
    still live in Rust). Same caveat as `make_ppo_update_b`: the lowered
    numerics match the per-agent executable to f32-reassociation
    tolerance; the native backend's row loop is the bit-identical one,
    pinned by `tests/native_retrain.rs`.

    (states[N, 3P+1], batches[N, 1 + prod(feats) + prod(labels)])
        -> states'[N, 3P+1]
    """
    row = make_aip_update(spec, adam_cfg, unravel, adim, batch_shape, label_shape)

    def update(states, batches):
        return jax.vmap(row)(states, batches)

    return update


def make_aip_eval(spec: AipSpec, unravel):
    """(flat, feats, labels) -> ce[1] — used for the Fig. 4 CE-loss curves."""

    def evaluate(flat, feats, labels):
        return aip_ce_loss(unravel(flat), spec, feats, labels).reshape(1)

    return evaluate


# --------------------------------------------------------------------------
# Flattening helpers
# --------------------------------------------------------------------------

def flatten_params(params):
    """-> (flat[P] f32, unravel_fn)."""
    flat, unravel = ravel_pytree(params)
    return flat.astype(jnp.float32), unravel
