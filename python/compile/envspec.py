"""Single source of truth for environment interface dimensions.

These constants define the contract between the Rust simulators (Layer 3)
and the compiled networks (Layers 1-2). aot.py copies them into each
``artifacts/<domain>.meta`` file and the Rust loader asserts they match its
own compile-time constants, so drift is caught at startup, not at runtime.
"""

# ---------------------------------------------------------------- traffic
# Local state of one intersection: binary occupancy of the 6 visible cells
# on each of the 4 incoming lanes (24), one-hot light phase (2: NS-green /
# EW-green), and time-in-phase normalised by the max phase length (1).
TRAFFIC_LANES = 4
TRAFFIC_VISIBLE_CELLS = 6
TRAFFIC_OBS = TRAFFIC_LANES * TRAFFIC_VISIBLE_CELLS + 2 + 1  # 27
TRAFFIC_ACT = 2  # keep phase / switch phase
# Influence sources: Bernoulli "a car enters lane l next tick" per lane.
TRAFFIC_N_SRC = TRAFFIC_LANES  # 4 heads, 1 logit each
TRAFFIC_U_DIM = TRAFFIC_N_SRC  # AIP output width (probabilities)
TRAFFIC_AIP_FEAT = TRAFFIC_OBS + TRAFFIC_ACT  # local state ⊕ one-hot action

# -------------------------------------------------------------- warehouse
# Local state of one robot: own-location bitmap over the 5×5 region (25)
# plus 12 binary item indicators on the shelf cells.
WAREHOUSE_REGION = 5
WAREHOUSE_ITEM_SLOTS = 12
WAREHOUSE_OBS = WAREHOUSE_REGION * WAREHOUSE_REGION + WAREHOUSE_ITEM_SLOTS  # 37
WAREHOUSE_ACT = 5  # up / down / left / right / stay
# Influence sources: for each of the 4 neighbour robots, a categorical over
# {3 shared shelf cells, "not on the shared edge"}.
WAREHOUSE_N_HEADS = 4
WAREHOUSE_N_CLS = 4
WAREHOUSE_U_DIM = WAREHOUSE_N_HEADS * WAREHOUSE_N_CLS  # 16 probabilities
WAREHOUSE_AIP_FEAT = WAREHOUSE_OBS + WAREHOUSE_ACT  # 42
