//! Fig. 3 (2a/2b): final return bars and (3a/3b): total-runtime bars
//! (log2 y-axis) for N ∈ {4, 25, 49, 100} agents, both domains; also
//! regenerates the appendix Fig. 5/6 runtime panels.
//!
//! Paper shape to reproduce: GS runtime grows steeply with N while the
//! DIALS *critical path* stays nearly flat (the paper's cluster measured
//! wall-clock with one process per agent; on this 1-CPU box the critical
//! path is the equivalent quantity — DESIGN.md substitution). The paper's
//! headline: 100 agents, DIALS ≈ 6h vs GS ≈ 10 days → speedup ≈ 40×.
//!
//!     cargo bench --offline --bench fig3_scaling
//!     cargo bench --offline --bench fig3_scaling -- --all-sizes --steps 2000

use anyhow::Result;

use dials::baselines::GsTrainer;
use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::{fmt_secs, Table};
use dials::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let steps = args.get_usize("steps", 1200)?;
    let sizes = if args.get_bool("all-sizes") {
        vec![2usize, 5, 7, 10]
    } else {
        args.get_usize_list("sizes", &[2, 5, 7])?
    };
    let engine = Engine::cpu()?;

    for domain in [Domain::Traffic, Domain::Warehouse] {
        let mut table = Table::new(
            &format!("Fig3 scaling — {} ({} steps/agent)", domain.name(), steps),
            &["agents", "mode", "final return", "wall(serial)", "critical path", "log2(CP s)"],
        );
        let mut cp: Vec<(usize, SimMode, f64)> = Vec::new();
        for &side in &sizes {
            for mode in [SimMode::GlobalSim, SimMode::Dials, SimMode::UntrainedDials] {
                let cfg = ExperimentConfig {
                    domain,
                    mode,
                    grid_side: side,
                    total_steps: steps,
                    aip_train_freq: (steps / 2).max(1),
                    aip_dataset: 300,
                    aip_epochs: 20,
                    eval_every: steps, // evaluate only at the end (runtime bench)
                    eval_episodes: 2,
                    horizon: 100,
                    seed: 0,
                    ..Default::default()
                };
                let coord = DialsCoordinator::new(&engine, cfg)?;
                let log = match mode {
                    SimMode::GlobalSim => GsTrainer::new(coord).run()?,
                    _ => coord.run()?,
                };
                table.row(vec![
                    format!("{}", side * side),
                    log.label.clone(),
                    format!("{:.3}", log.final_return),
                    fmt_secs(log.wall_seconds),
                    fmt_secs(log.critical_path_seconds),
                    format!("{:.2}", log.critical_path_seconds.max(1e-9).log2()),
                ]);
                cp.push((side * side, mode, log.critical_path_seconds));
            }
        }
        table.print();
        table.save_csv(&format!("fig3_scaling_{}", domain.name()));

        // paper-shape summary: speedup(GS/DIALS) should grow with N
        println!("speedup (GS critical path / DIALS critical path):");
        for &side in &sizes {
            let n = side * side;
            let gs = cp.iter().find(|x| x.0 == n && x.1 == SimMode::GlobalSim).unwrap().2;
            let di = cp.iter().find(|x| x.0 == n && x.1 == SimMode::Dials).unwrap().2;
            println!("  {n:>4} agents: {:.1}x", gs / di.max(1e-9));
        }
    }
    Ok(())
}
