//! Table 3 (App. H): peak memory usage — GS vs DIALS per-process / total.
//!
//! The original measured per-process RSS (one OS process per simulator).
//! Here simulators are in-process workers, so a global tracking allocator
//! measures: (a) peak heap of constructing + stepping the GS, and (b) peak
//! heap per DIALS worker (local sim + AIP + policy + dataset + buffers),
//! with DIALS total = per-worker × N.
//!
//! Paper shape to reproduce: GS memory grows sub-linearly with N; DIALS
//! per-process memory stays ~constant; DIALS total grows linearly with N
//! and overtakes the GS (the paper's stated trade-off).
//!
//!     cargo bench --offline --bench table3_memory -- --sizes 2,5,7,10

use anyhow::Result;

use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::{make_global_sim, DialsCoordinator};
use dials::runtime::Engine;
use dials::util::alloc::{measure_peak, TrackingAlloc};
use dials::util::bench::Table;
use dials::util::cli::Args;
use dials::util::rng::Pcg64;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn mb(bytes: usize) -> String {
    format!("{:.3}", bytes as f64 / 1e6)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let sizes = args.get_usize_list("sizes", &[2, 5, 7, 10])?;
    let engine = Engine::cpu()?;

    for domain in [Domain::Traffic, Domain::Warehouse] {
        let mut table = Table::new(
            &format!("Table 3 — peak heap (MB), {}", domain.name()),
            &["agents", "GS", "DIALS per-worker", "DIALS total"],
        );
        for &side in &sizes {
            let n = side * side;
            // (a) global simulator: construct + step through 2 episodes
            let (_, gs_peak) = measure_peak(|| {
                let mut gs = make_global_sim(domain, side);
                let mut rng = Pcg64::seed(0);
                gs.reset(&mut rng);
                let acts = vec![0usize; n];
                let mut rewards = vec![0.0f32; n];
                for _ in 0..200 {
                    gs.step(&acts, &mut rewards, &mut rng);
                }
                gs.n_agents()
            });

            // (b) one DIALS worker: nets + AIP + dataset + buffer + LS
            let cfg = ExperimentConfig {
                domain,
                mode: SimMode::Dials,
                grid_side: side,
                aip_dataset: 300,
                ..Default::default()
            };
            let coord = DialsCoordinator::new(&engine, cfg)?;
            let (_, worker_peak) = measure_peak(|| {
                let workers = coord.make_workers(0);
                workers.len()
            });
            let per_worker = worker_peak / n;

            table.row(vec![
                format!("{n}"),
                mb(gs_peak),
                mb(per_worker),
                mb(per_worker * n),
            ]);
        }
        table.print();
        table.save_csv(&format!("table3_memory_{}", domain.name()));
    }
    println!("\nNote: heap-only accounting (the PJRT runtime and compiled
executables are shared across workers in-process and excluded, matching the
paper's per-simulator-process comparison).");
    Ok(())
}
