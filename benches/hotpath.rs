//! Hot-path microbenchmarks (§Perf deliverable, not a paper table).
//!
//! Measures every component on the per-step critical path so the perf pass
//! can attribute time — simulator steps, PJRT executable invocations
//! (policy forward, AIP forward), the PPO/AIP update calls, and the
//! end-to-end per-agent step of the IALS training loop — AND, since the
//! zero-allocation step refactor, the heap traffic of each loop via the
//! tracking allocator (`util::alloc`):
//!
//! * the steady-state simulator loops (traffic/warehouse GS + LS with the
//!   buffer-out `step` API) must allocate ZERO bytes per step — the bench
//!   fails loudly if they regress;
//! * the NN-in-the-loop paths report bytes/step AND `run_b` calls per
//!   joint GS step, so the batch-first trajectory (N B=1 calls → 1
//!   batched call, ROADMAP) is comparable across PRs;
//! * the batch-first section runs on the native backend with synthesized
//!   artifacts (`runtime::synth`) — no `make artifacts` needed — and
//!   measures `evaluate_on_gs` end-to-end in batched vs per-agent mode;
//! * the megabatch section runs LS training with R vectorized replicas
//!   per agent (R ∈ {1, 8, 64}, both domains) behind one `[N*R]`-row
//!   forward and reports `ls_steps_per_s` — trained env steps per second
//!   across ALL replicas, the headline scaling number of the megabatch
//!   redesign — plus the two-batched-calls-per-tick invariant;
//! * the fused-update section re-runs megabatch training WITH native PPO
//!   updates (R ∈ {8, 64, 512}) in fused (`ppo_update_b`, one call chain
//!   for all N agents) vs per-agent fallback mode and reports
//!   `update_wall_s` — the update share of the segment wall, growth-gated
//!   by tools/bench_diff — plus heap bytes per update;
//! * the AIP-retrain section times one whole-system influence retrain
//!   (N agents × epochs cross-entropy Adam steps) fused (`aip_update_b`)
//!   vs per-agent fallback and reports `aip_update_wall_s`, growth-gated
//!   by tools/bench_diff;
//! * the distributed-GS section steps `DistPlan` over loopback shard
//!   workers (real wire frames + serve loops, in-process transport) at
//!   procs ∈ {1, 2, 4} in both domains and reports `dist_steps_per_s`
//!   — joint GS steps per second through the process-boundary protocol,
//!   growth-gated by tools/bench_diff.
//!
//! Results are printed, saved as `results/hotpath.csv`, and emitted as
//! machine-readable `BENCH_hotpath.json` in the working directory (CI
//! uploads the JSON as a workflow artifact). Sections that need compiled
//! artifacts skip with a notice when `make artifacts` has not run (or the
//! `xla` feature is off).
//!
//!     cargo bench --offline --bench hotpath

use anyhow::Result;

use dials::config::{Domain, ExperimentConfig, PpoConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::exec::WorkerPool;
use dials::ppo::PpoTrainer;
use dials::runtime::Engine;
use dials::sim::traffic::TrafficLocalSim;
use dials::sim::warehouse::WarehouseLocalSim;
use dials::sim::{traffic::TrafficGlobalSim, warehouse::WarehouseGlobalSim, GlobalSim, LocalSim};
use dials::util::alloc::{self, TrackingAlloc};
use dials::util::bench::{time_n, Table};
use dials::util::npk::Tensor;
use dials::util::rng::Pcg64;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

/// One benchmark row destined for BENCH_hotpath.json.
struct JsonRow {
    op: String,
    mean_s: f64,
    min_s: f64,
    bytes_per_step: f64,
    peak_extra_bytes: usize,
    /// `run_b` executions per joint GS step (NaN = not applicable).
    calls_per_step: f64,
    /// GS-phase joint steps per second (NaN = not a GS stepping row).
    steps_per_s: f64,
    /// Megabatch LS training throughput: trained env steps per second
    /// summed across all N*R replicas (NaN = not a megabatch row).
    ls_steps_per_s: f64,
    /// Seconds spent inside the fill-tick PPO update phases of one
    /// megabatch training segment (the fused-vs-per-agent comparison;
    /// NaN = not an update row). Gated by bench_diff.
    update_wall_s: f64,
    /// End-to-end wall seconds of a training run whose segments and GS
    /// evaluations may overlap — the blocking-vs-async eval comparison
    /// (NaN = not a segment+eval row).
    seg_eval_wall_s: f64,
    /// On-critical-path influence-collection seconds of a coordinator run
    /// (`RunLog::influence_seconds` with `aip_epochs = 0`) — the
    /// blocking-vs-async collect comparison (NaN = not a collect row).
    collect_wall_s: f64,
    /// Wall seconds of one whole-system AIP retrain (N agents × `epochs`
    /// gradient steps) — the fused-vs-per-agent comparison (NaN = not an
    /// AIP retrain row). Gated by bench_diff.
    aip_update_wall_s: f64,
    /// `dials serve` end-to-end request latency percentiles in
    /// microseconds (NaN = not a serve row). Gated by bench_diff.
    serve_p50_us: f64,
    serve_p99_us: f64,
    /// Joint GS steps per second through the multi-process `DistPlan`
    /// loopback protocol (NaN = not a dist row). Gated by bench_diff.
    dist_steps_per_s: f64,
}

/// Heap traffic of `steps` iterations of `f` after a warm-up pass:
/// (net live bytes per step, peak extra bytes over the whole window).
fn alloc_per_step(steps: usize, mut f: impl FnMut()) -> (f64, usize) {
    for _ in 0..steps.min(64) {
        f(); // warm-up: scratch buffers reach steady-state capacity
    }
    alloc::reset_peak();
    let before = alloc::snapshot();
    for _ in 0..steps {
        f();
    }
    let after = alloc::snapshot();
    let net = after.live as f64 - before.live as f64;
    (net / steps as f64, after.peak.saturating_sub(before.live))
}

fn main() -> Result<()> {
    let mut table = Table::new(
        "hot path microbenchmarks",
        &[
            "op", "mean", "min", "per-unit", "B/step", "peak extra", "calls/step", "steps/s",
            "ls steps/s", "upd wall", "seg+eval wall", "collect wall", "aip wall", "serve p50",
            "serve p99", "dist steps/s",
        ],
    );
    let mut json: Vec<JsonRow> = Vec::new();
    let reps = 200;
    let mut sim_zero_alloc = true;

    // ---- simulators (always run; must be allocation-free per step)
    {
        let mut rng = Pcg64::seed(0);

        let mut ls = TrafficLocalSim::new();
        ls.reset(&mut rng);
        let (mean, min) = time_n(reps, || {
            ls.step(0, &[1.0, 0.0, 0.0, 0.0], &mut rng);
        });
        let (bps, peak) = alloc_per_step(512, || {
            ls.step(0, &[1.0, 0.0, 0.0, 0.0], &mut rng);
        });
        sim_zero_alloc &= bps == 0.0 && peak == 0;
        push_row(&mut table, &mut json, "traffic LS step", mean, min, "1 step", bps, peak, f64::NAN);

        let mut wls = WarehouseLocalSim::new();
        wls.reset(&mut rng);
        let (mean, min) = time_n(reps, || {
            wls.step(1, &[3.0, 3.0, 3.0, 3.0], &mut rng);
        });
        let (bps, peak) = alloc_per_step(512, || {
            wls.step(1, &[3.0, 3.0, 3.0, 3.0], &mut rng);
        });
        sim_zero_alloc &= bps == 0.0 && peak == 0;
        push_row(&mut table, &mut json, "warehouse LS step", mean, min, "1 step", bps, peak, f64::NAN);

        let mut gs = TrafficGlobalSim::new(5);
        gs.reset(&mut rng);
        let acts = vec![0usize; 25];
        let mut rewards = vec![0.0f32; 25];
        let (mean, min) = time_n(reps, || {
            gs.step(&acts, &mut rewards, &mut rng);
        });
        let (bps, peak) = alloc_per_step(512, || {
            gs.step(&acts, &mut rewards, &mut rng);
        });
        sim_zero_alloc &= bps == 0.0 && peak == 0;
        push_row_steps(&mut table, &mut json, "traffic GS step (25 ints)", mean, min, "25 agents", bps, peak, f64::NAN, 1.0 / mean);

        let mut wgs = WarehouseGlobalSim::new(5);
        wgs.reset(&mut rng);
        let (mean, min) = time_n(reps, || {
            wgs.step(&acts, &mut rewards, &mut rng);
        });
        let (bps, peak) = alloc_per_step(512, || {
            wgs.step(&acts, &mut rewards, &mut rng);
        });
        sim_zero_alloc &= bps == 0.0 && peak == 0;
        push_row_steps(&mut table, &mut json, "warehouse GS step (25 rb)", mean, min, "25 agents", bps, peak, f64::NAN, 1.0 / mean);
    }

    // ---- sharded GS stepping (PartitionedGs scatter/merge on the pool)
    //
    // The tentpole claim: the GS dynamics step — the last serial phase on
    // the critical path — now scales with cores. Serial `GlobalSim::step`
    // vs `ShardPlan::step` at shards = 1/2/8 on a grid large enough that
    // one joint step dominates the pool's phase overhead. Results are
    // bit-identical across shard counts (tests/shard_equivalence.rs);
    // here we measure throughput only.
    {
        use dials::sim::ShardPlan;

        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let pool = WorkerPool::new(threads);
        let mut speedup_8 = f64::NAN;

        // traffic: the CA advance dominates — the showcase domain
        let side = 48usize; // 2304 intersections (the bench grid)
        let n = side * side;
        let acts: Vec<usize> = (0..n).map(|i| (i % 9 == 0) as usize).collect();
        let mut rewards = vec![0.0f32; n];

        let serial_mean = {
            let mut gs = TrafficGlobalSim::new(side);
            let mut rng = Pcg64::seed(17);
            gs.reset(&mut rng);
            for _ in 0..32 {
                gs.step(&acts, &mut rewards, &mut rng); // warm the grid
            }
            let (mean, min) = time_n(reps, || {
                gs.step(&acts, &mut rewards, &mut rng);
            });
            let (bps, peak) = alloc_per_step(64, || {
                gs.step(&acts, &mut rewards, &mut rng);
            });
            push_row_steps(
                &mut table, &mut json,
                &format!("traffic GS step serial ({n} ints)"),
                mean, min, "1 joint step", bps, peak, f64::NAN, 1.0 / mean,
            );
            mean
        };
        for shards in [1usize, 2, 8] {
            let mut gs = TrafficGlobalSim::new(side);
            let mut plan = ShardPlan::new(n, shards);
            let mut rng = Pcg64::seed(17);
            gs.reset(&mut rng);
            plan.reseed(&mut rng);
            for _ in 0..32 {
                plan.step(&mut gs, &pool, &acts, &mut rewards).unwrap();
            }
            let (mean, min) = time_n(reps, || {
                plan.step(&mut gs, &pool, &acts, &mut rewards).unwrap();
            });
            // bytes/step here is the pool's per-phase bookkeeping (the
            // sim-layer shard buffers are persistent) — measured, not
            // asserted zero like the serial sim rows.
            let (bps, peak) = alloc_per_step(64, || {
                plan.step(&mut gs, &pool, &acts, &mut rewards).unwrap();
            });
            if shards == 8 {
                speedup_8 = serial_mean / mean;
            }
            push_row_steps(
                &mut table, &mut json,
                &format!("traffic GS step sharded x{shards} ({n} ints, {threads} thr)"),
                mean, min, "1 joint step", bps, peak, f64::NAN, 1.0 / mean,
            );
        }

        // warehouse: the merge (labels/collection/aging) dominates, so
        // this row mostly measures the protocol's overhead floor
        let wside = 16usize; // 256 robots
        let wn = wside * wside;
        let wacts: Vec<usize> = (0..wn).map(|i| i % 5).collect();
        let mut wrewards = vec![0.0f32; wn];
        {
            let mut gs = WarehouseGlobalSim::new(wside);
            let mut rng = Pcg64::seed(19);
            gs.reset(&mut rng);
            let (mean, min) = time_n(reps, || {
                gs.step(&wacts, &mut wrewards, &mut rng);
            });
            let (bps, peak) = alloc_per_step(64, || {
                gs.step(&wacts, &mut wrewards, &mut rng);
            });
            push_row_steps(
                &mut table, &mut json,
                &format!("warehouse GS step serial ({wn} rb)"),
                mean, min, "1 joint step", bps, peak, f64::NAN, 1.0 / mean,
            );
        }
        for shards in [1usize, 8] {
            let mut gs = WarehouseGlobalSim::new(wside);
            let mut plan = ShardPlan::new(wn, shards);
            let mut rng = Pcg64::seed(19);
            gs.reset(&mut rng);
            plan.reseed(&mut rng);
            let (mean, min) = time_n(reps, || {
                plan.step(&mut gs, &pool, &wacts, &mut wrewards).unwrap();
            });
            let (bps, peak) = alloc_per_step(64, || {
                plan.step(&mut gs, &pool, &wacts, &mut wrewards).unwrap();
            });
            push_row_steps(
                &mut table, &mut json,
                &format!("warehouse GS step sharded x{shards} ({wn} rb, {threads} thr)"),
                mean, min, "1 joint step", bps, peak, f64::NAN, 1.0 / mean,
            );
        }

        println!(
            "\nsharded GS speedup @ 8 shards (traffic, {n} ints, {threads} threads): \
             {speedup_8:.2}x over serial"
        );
    }

    // ---- multi-process GS stepping (DistPlan over loopback workers)
    //
    // The process-boundary twin of the sharded rows: every joint step
    // round-trips scoped actions, boundary-event sync, and shard state
    // through the real wire codec and worker serve loops (in-process
    // channel transport — no socket syscalls, so the rows isolate the
    // protocol cost: encode/decode, state export/import, merge). Results
    // are bit-identical to `--gs-shards` at every process count
    // (tests/dist_equivalence.rs); `dist steps/s` is throughput only and
    // is growth-gated by tools/bench_diff.
    {
        use dials::coordinator::make_global_sim;
        use dials::dist::DistPlan;

        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let pool = WorkerPool::new(threads);
        for (domain, side) in [(Domain::Traffic, 24usize), (Domain::Warehouse, 8)] {
            for procs in [1usize, 2, 4] {
                let mut gs = make_global_sim(domain, side);
                let n = gs.n_agents();
                let acts: Vec<usize> = (0..n).map(|i| i % gs.n_actions()).collect();
                let mut rewards = vec![0.0f32; n];
                let mut plan = DistPlan::loopback(procs, domain, side, gs.as_mut())?;
                let mut rng = Pcg64::seed(31);
                let raw = rng.to_raw();
                gs.reset(&mut rng);
                plan.reseed(raw, &mut rng);
                for _ in 0..16 {
                    plan.step(gs.as_mut(), &pool, &acts, &mut rewards)?; // warm up
                }
                let (mean, min) = time_n(64, || {
                    plan.step(gs.as_mut(), &pool, &acts, &mut rewards).unwrap();
                });
                // No thread count in the op name: the rows must match the
                // committed baseline across runners (threads only shift
                // throughput, which the 20% tolerance absorbs).
                push_row_dist(
                    &mut table, &mut json,
                    &format!("{} dist GS step x{procs} procs (N={n})", domain.name()),
                    mean, min, "1 joint step", 1.0 / mean,
                );
            }
        }
    }

    // ---- PJRT executable calls + e2e training step (need artifacts)
    let engine = Engine::cpu()?;
    for domain in [Domain::Traffic, Domain::Warehouse] {
        let cfg = ExperimentConfig {
            domain,
            mode: SimMode::Dials,
            ppo: PpoConfig::default(),
            ..Default::default()
        };
        if !cfg!(feature = "xla") {
            eprintln!("SKIP: built without the `xla` feature; NN-path rows omitted");
            break;
        }
        let meta = std::path::Path::new(&cfg.artifacts_dir).join(format!("{}.meta", domain.name()));
        if !meta.is_file() {
            eprintln!(
                "SKIP: {} artifacts not built (run `make artifacts`); NN-path rows omitted",
                domain.name()
            );
            continue;
        }
        let coord = DialsCoordinator::new(&engine, cfg.clone())?;
        let arts = coord.artifacts();
        let spec = &arts.spec;
        let params = arts.policy_init.clone();
        let obs = Tensor::zeros(&[1, spec.obs_dim]);
        let h = Tensor::zeros(&[1, spec.policy_hstate]);
        let (mean, min) = time_n(reps, || {
            arts.policy_step.run(&[params.clone(), obs.clone(), h.clone()]).unwrap();
        });
        let (bps, peak) = alloc_per_step(reps, || {
            arts.policy_step.run(&[params.clone(), obs.clone(), h.clone()]).unwrap();
        });
        push_row(&mut table, &mut json, &format!("{} policy_step HLO call", domain.name()), mean, min, "1 fwd", bps, peak, f64::NAN);

        let ap = arts.aip_init.clone();
        let feat = Tensor::zeros(&[1, spec.aip_feat]);
        let ah = Tensor::zeros(&[1, spec.aip_hstate]);
        let (mean, min) = time_n(reps, || {
            arts.aip_forward.run(&[ap.clone(), feat.clone(), ah.clone()]).unwrap();
        });
        let (bps, peak) = alloc_per_step(reps, || {
            arts.aip_forward.run(&[ap.clone(), feat.clone(), ah.clone()]).unwrap();
        });
        push_row(&mut table, &mut json, &format!("{} aip_forward HLO call", domain.name()), mean, min, "1 fwd", bps, peak, f64::NAN);

        // full PPO update (epochs × minibatches over one rollout)
        let mut workers = coord.make_workers(0);
        let w = &mut workers[0];
        let trainer = PpoTrainer::new(cfg.ppo.clone());
        // fill one rollout via real stepping
        w.train_segment(arts, &trainer, cfg.ppo.rollout_len, cfg.horizon)?;
        let mut rng = Pcg64::seed(1);
        // measure the raw update call on a synthetic full buffer
        let mut buf =
            dials::ppo::RolloutBuffer::new(cfg.ppo.rollout_len, spec.obs_dim, spec.policy_hstate);
        let obs_row = vec![0.1f32; spec.obs_dim];
        let h_row = vec![0.0f32; spec.policy_hstate];
        for t in 0..cfg.ppo.rollout_len {
            buf.push(&obs_row, &h_row, t % spec.act_dim, -0.5, 0.3, 0.2, t % cfg.horizon == cfg.horizon - 1);
        }
        let (mean, min) = time_n(20, || {
            trainer.update(arts, &mut w.policy.net, &buf, 0.0, &mut rng).unwrap();
        });
        let calls = cfg.ppo.epochs * (cfg.ppo.rollout_len / cfg.ppo.minibatch);
        push_row(&mut table, &mut json, &format!("{} PPO update (rollout)", domain.name()), mean, min, &format!("{calls} HLO calls"), f64::NAN, 0, f64::NAN);

        // end-to-end IALS training step (post-warmup steady state)
        let (mean, min) = time_n(20, || {
            w.train_segment(arts, &trainer, 32, cfg.horizon).unwrap();
        });
        let (bytes_32, peak) = alloc_per_step(20, || {
            w.train_segment(arts, &trainer, 32, cfg.horizon).unwrap();
        });
        push_row(
            &mut table, &mut json,
            &format!("{} IALS train step e2e", domain.name()),
            mean / 32.0, min / 32.0, "per env step", bytes_32 / 32.0, peak, f64::NAN,
        );
    }

    // ---- batch-first GS stepping (native backend; synthesized artifacts)
    //
    // Measures evaluate_on_gs end-to-end in both bank modes and reports
    // the run_b calls per joint GS step — the headline number of the
    // batch-first redesign (N B=1 calls → 1 batched call).
    #[cfg(not(feature = "xla"))]
    for domain in [Domain::Traffic, Domain::Warehouse] {
        use dials::coordinator::{evaluate_on_gs, make_global_sim, GsScratch};
        use dials::runtime::synth;

        let dir = std::env::temp_dir().join("dials_hotpath_synth").join(domain.name());
        let _ = std::fs::remove_dir_all(&dir);
        synth::write_native_artifacts(&dir, domain, 3)?;
        let cfg = ExperimentConfig {
            domain,
            mode: SimMode::Dials,
            grid_side: 5,
            artifacts_dir: dir.to_string_lossy().into_owned(),
            ..Default::default()
        };
        let n = cfg.n_agents();
        let coord = DialsCoordinator::new(&engine, cfg.clone())?;
        let arts = coord.artifacts();
        let horizon = 16usize;
        for (label, batched) in [("batched", true), ("per-agent", false)] {
            let mut workers = coord.make_workers(0);
            let mut gs = make_global_sim(cfg.domain, cfg.grid_side);
            let mut rng = Pcg64::seed(7);
            let mut scratch = GsScratch::new(&arts.spec, n, batched);
            let pool = WorkerPool::new(1);
            let calls_before = arts.policy_step.call_count()
                + arts.policy_step_b.as_ref().map_or(0, |e| e.call_count());
            let mut episodes = 0u64;
            let (mean, min) = time_n(8, || {
                evaluate_on_gs(
                    arts, gs.as_mut(), &mut workers, 1, horizon, &mut rng, &mut scratch, &pool,
                )
                .unwrap();
                episodes += 1;
            });
            let (bytes_ep, peak) = alloc_per_step(8, || {
                evaluate_on_gs(
                    arts, gs.as_mut(), &mut workers, 1, horizon, &mut rng, &mut scratch, &pool,
                )
                .unwrap();
                episodes += 1;
            });
            let calls_after = arts.policy_step.call_count()
                + arts.policy_step_b.as_ref().map_or(0, |e| e.call_count());
            let joint_steps = episodes * horizon as u64;
            let cps = (calls_after - calls_before) as f64 / joint_steps as f64;
            push_row_steps(
                &mut table, &mut json,
                &format!("{} GS eval joint step ({label}, N={n})", domain.name()),
                mean / horizon as f64, min / horizon as f64,
                "per joint step", bytes_ep / horizon as f64, peak, cps,
                horizon as f64 / mean,
            );
        }
    }

    // ---- megabatch LS training (native backend; synthesized artifacts)
    //
    // R vectorized LS replicas per agent behind one [N*R]-row forward —
    // exactly two batched run calls per joint tick, call-count-pinned by
    // tests/megabatch_equivalence.rs and reported as calls/step here.
    // `ls_steps_per_s` counts trained env steps summed across ALL N*R
    // replicas: scaling with R is the megabatch win (the per-tick wall
    // barely grows while the trained-step volume multiplies).
    #[cfg(not(feature = "xla"))]
    for domain in [Domain::Traffic, Domain::Warehouse] {
        use dials::coordinator::LsMegabatch;
        use dials::runtime::synth;

        let dir = std::env::temp_dir()
            .join("dials_hotpath_synth")
            .join(format!("mega_{}", domain.name()));
        let _ = std::fs::remove_dir_all(&dir);
        synth::write_native_artifacts(&dir, domain, 3)?;
        let horizon = 32usize;
        for reps_per_agent in [1usize, 8, 64] {
            let cfg = ExperimentConfig {
                domain,
                mode: SimMode::UntrainedDials,
                grid_side: 2,
                horizon,
                // rollout never fills inside the measured window: the rows
                // isolate the batched tick path (PPO updates are costed by
                // their own row above)
                ppo: PpoConfig { rollout_len: 1024, minibatch: 32, epochs: 1, ..Default::default() },
                artifacts_dir: dir.to_string_lossy().into_owned(),
                ls_replicas: reps_per_agent,
                ..Default::default()
            };
            let n = cfg.n_agents();
            let coord = DialsCoordinator::new(&engine, cfg.clone())?;
            let arts = coord.artifacts();
            let trainer = PpoTrainer::new(cfg.ppo.clone());
            let mut workers = coord.make_workers(cfg.seed);
            let mut mega = LsMegabatch::new(arts, &cfg, &workers, reps_per_agent);
            let pool = WorkerPool::new(1);
            // warm-up: first-tick resets, device slots, scratch capacity
            mega.train_segment(arts, &trainer, &mut workers, &pool, 16, horizon)?;
            let calls_before = arts.policy_step_b.as_ref().map_or(0, |e| e.call_count())
                + arts.aip_forward_b.as_ref().map_or(0, |e| e.call_count());
            let ticks_per_iter = 64usize;
            let mut iters = 0u64;
            let (mean, min) = time_n(3, || {
                mega.train_segment(arts, &trainer, &mut workers, &pool, ticks_per_iter, horizon)
                    .unwrap();
                iters += 1;
            });
            let calls_after = arts.policy_step_b.as_ref().map_or(0, |e| e.call_count())
                + arts.aip_forward_b.as_ref().map_or(0, |e| e.call_count());
            let ticks = iters * ticks_per_iter as u64;
            let cps = (calls_after - calls_before) as f64 / ticks as f64;
            let ls_sps = (n * reps_per_agent * ticks_per_iter) as f64 / mean;
            push_row_ls(
                &mut table, &mut json,
                &format!("{} megabatch LS train x{reps_per_agent} (N={n})", domain.name()),
                mean / ticks_per_iter as f64, min / ticks_per_iter as f64,
                "per joint tick", cps, ls_sps,
            );
        }
    }

    // ---- fused [N]-wide PPO updates on the megabatch fill-tick path
    //
    // Giant-R training WITH real native updates: rollout 16 fills twice in
    // a 32-tick segment, so each measured segment pays 2 fill ticks of
    // `epochs × minibatches` PPO update calls — ONE `ppo_update_b` chain
    // for all N agents on the fused path vs N per-agent `ppo_update`
    // chains on the fallback (the same artifact set with `ppo_update_b`
    // stripped). `upd wall` is the update share of the segment wall
    // (growth-gated by tools/bench_diff); B/step is heap bytes per PPO
    // update — the forward ticks are allocation-free in steady state
    // (tests/megabatch_alloc.rs), so the whole segment's traffic is the
    // updates', and the fused rows undercutting the per-agent rows is the
    // saved-bytes-per-update number of the device-chained state redesign.
    // `ls steps/s` now includes update cost: the R = 512 fused row beating
    // its per-agent twin is the headline of this PR.
    #[cfg(not(feature = "xla"))]
    {
        use dials::coordinator::LsMegabatch;
        use dials::runtime::{synth, ArtifactSet};

        let domain = Domain::Traffic;
        let dir = std::env::temp_dir().join("dials_hotpath_synth").join("fused_update");
        let _ = std::fs::remove_dir_all(&dir);
        synth::write_native_artifacts(&dir, domain, 3)?;
        let horizon = 32usize;
        let ticks_per_iter = 32usize; // 2 fill ticks at rollout 16
        let fills_per_iter = 2.0f64;
        let mut stripped = ArtifactSet::load(&engine, &dir, domain)?;
        std::sync::Arc::get_mut(&mut stripped).unwrap().ppo_update_b = None;
        for reps_per_agent in [8usize, 64, 512] {
            let cfg = ExperimentConfig {
                domain,
                mode: SimMode::UntrainedDials,
                grid_side: 2,
                horizon,
                ppo: PpoConfig { rollout_len: 16, minibatch: 16, epochs: 1, ..Default::default() },
                artifacts_dir: dir.to_string_lossy().into_owned(),
                ls_replicas: reps_per_agent,
                ..Default::default()
            };
            let n = cfg.n_agents();
            let coord = DialsCoordinator::new(&engine, cfg.clone())?;
            let trainer = PpoTrainer::new(cfg.ppo.clone());
            let pool = WorkerPool::new(1);
            for (label, arts) in
                [("fused", coord.artifacts().as_ref()), ("per-agent", stripped.as_ref())]
            {
                let mut workers = coord.make_workers(cfg.seed);
                let mut mega = LsMegabatch::new(arts, &cfg, &workers, reps_per_agent);
                // warm-up: one full segment incl. a fill tick (device
                // slots, bank upload, scratch capacity)
                mega.train_segment(arts, &trainer, &mut workers, &pool, ticks_per_iter, horizon)?;
                let mut iters = 0u64;
                let mut update_wall = 0.0f64;
                let (mean, min) = time_n(3, || {
                    let (_, upd) = mega
                        .train_segment(
                            arts, &trainer, &mut workers, &pool, ticks_per_iter, horizon,
                        )
                        .unwrap();
                    update_wall += upd;
                    iters += 1;
                });
                let upd_per_iter = update_wall / iters as f64;
                let (bytes_iter, peak) = alloc_per_step(3, || {
                    mega.train_segment(
                        arts, &trainer, &mut workers, &pool, ticks_per_iter, horizon,
                    )
                    .unwrap();
                });
                let ls_sps = (n * reps_per_agent * ticks_per_iter) as f64 / mean;
                push_row_update(
                    &mut table, &mut json,
                    &format!(
                        "{} megabatch PPO update x{reps_per_agent} ({label}, N={n})",
                        domain.name()
                    ),
                    mean / ticks_per_iter as f64, min / ticks_per_iter as f64,
                    "per joint tick", bytes_iter / fills_per_iter, peak, ls_sps, upd_per_iter,
                );
            }
        }
    }

    // ---- async GS evaluation overlapped with training segments
    //
    // The tentpole comparison: the same coordinator run (untrained-DIALS,
    // forward-only so the native backend runs it end-to-end) with blocking
    // evaluation at every boundary vs evaluation deferred onto the pool
    // (`cfg.async_eval = 2`, the double buffer). The row's wall column is
    // the full segments+eval wall clock — overlap shows up as the async
    // row undercutting the blocking one. Curves are bit-identical either
    // way (tests/async_eval_equivalence.rs); this measures time only.
    #[cfg(not(feature = "xla"))]
    {
        use dials::runtime::synth;

        let domain = Domain::Traffic;
        let dir = std::env::temp_dir().join("dials_hotpath_synth").join("async_eval");
        let _ = std::fs::remove_dir_all(&dir);
        synth::write_native_artifacts(&dir, domain, 3)?;
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let mk_cfg = |async_eval: usize| ExperimentConfig {
            domain,
            mode: SimMode::UntrainedDials,
            grid_side: 4,
            total_steps: 240,
            aip_train_freq: 240,
            eval_every: 60,
            eval_episodes: 4,
            horizon: 60,
            seed: 11,
            // rollout never fills: segments are pure forward+LS stepping,
            // which the native backend executes for real
            ppo: PpoConfig { rollout_len: 512, minibatch: 32, epochs: 1, ..Default::default() },
            artifacts_dir: dir.to_string_lossy().into_owned(),
            async_eval,
            ..Default::default()
        };
        let mut walls = [f64::NAN; 2];
        for (k, (label, depth)) in [("blocking eval", 0usize), ("async eval x2", 2)]
            .into_iter()
            .enumerate()
        {
            let coord = DialsCoordinator::new(&engine, mk_cfg(depth))?;
            let (mean, min) = time_n(3, || {
                coord.run().unwrap();
            });
            walls[k] = mean;
            push_row_full(
                &mut table, &mut json,
                &format!("coordinator run, {label} (16 agents)"),
                mean, min, "4 segs + 5 evals", f64::NAN, 0, f64::NAN, f64::NAN, f64::NAN,
                f64::NAN, mean, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
            );
        }
        println!(
            "\nsegment+eval overlap (traffic, 16 agents, {threads} threads): blocking \
             {:.3}s vs async {:.3}s -> {:.2}x",
            walls[0], walls[1], walls[0] / walls[1]
        );
    }

    // ---- pipelined influence collection overlapped with a segment
    //
    // The DIALS-mode twin of the eval comparison (native aip_eval makes
    // the CE probes run without XLA; aip_epochs = 0 keeps the update
    // artifacts out). Two retrains: step 0 (degenerate — nothing precedes
    // it) and step 120, whose Algorithm-2 collection is snapshotted at
    // the preceding boundary (step 60) and overlaps the [60, 120)
    // training segment under `--async-collect 1`. The row's collect-wall
    // column is the run's ON-PATH influence time (collect snapshot +
    // inline loop or residual drain stall; AIP retrain cost is ~0 at 0
    // epochs) — the async row undercutting the blocking one is the
    // overlap win. Datasets/curves are bit-identical either way
    // (tests/async_collect_equivalence.rs); this measures time only.
    #[cfg(not(feature = "xla"))]
    {
        use dials::runtime::synth;

        let domain = Domain::Traffic;
        let dir = std::env::temp_dir().join("dials_hotpath_synth").join("async_collect");
        let _ = std::fs::remove_dir_all(&dir);
        synth::write_native_artifacts(&dir, domain, 3)?;
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let mk_cfg = |async_collect: usize| ExperimentConfig {
            domain,
            mode: SimMode::Dials,
            grid_side: 4,
            total_steps: 240,
            aip_train_freq: 120,
            aip_dataset: 400,
            aip_epochs: 0,
            eval_every: 60,
            eval_episodes: 2,
            horizon: 60,
            seed: 13,
            // rollout never fills: segments are pure forward+LS stepping,
            // which the native backend executes for real
            ppo: PpoConfig { rollout_len: 512, minibatch: 32, epochs: 1, ..Default::default() },
            artifacts_dir: dir.to_string_lossy().into_owned(),
            async_collect,
            ..Default::default()
        };
        let mut collect_walls = [f64::NAN; 2];
        for (k, (label, mode)) in [("blocking collect", 0usize), ("async collect", 1)]
            .into_iter()
            .enumerate()
        {
            let coord = DialsCoordinator::new(&engine, mk_cfg(mode))?;
            let mut influence = 0.0f64;
            let mut runs = 0u32;
            let (mean, min) = time_n(3, || {
                let log = coord.run().unwrap();
                influence += log.influence_seconds;
                runs += 1;
            });
            collect_walls[k] = influence / runs as f64;
            push_row_collect(
                &mut table, &mut json,
                &format!("coordinator run, {label} (16 agents)"),
                mean, min, "2 retrains + 5 evals", collect_walls[k],
            );
        }
        println!(
            "\nsegment+collect overlap (traffic, 16 agents, {threads} threads): blocking \
             {:.3}s vs async {:.3}s on-path collect -> {:.2}x",
            collect_walls[0], collect_walls[1], collect_walls[0] / collect_walls[1]
        );
    }

    // ---- fused [N]-wide AIP retrains on the native CE backward kernels
    //
    // The influence twin of the fused-PPO section: one whole-system AIP
    // retrain (N agents x `epochs` cross-entropy Adam steps over their
    // influence datasets) on the fused path — `aip_update_b`, one call
    // per epoch for all N packed state rows — vs the per-agent
    // `dataset.train` fallback the coordinator drops to when the batched
    // executable is absent. Results are bit-identical either way
    // (tests/native_retrain.rs); the aip-wall column is the wall seconds
    // of one whole retrain, growth-gated by tools/bench_diff.
    #[cfg(not(feature = "xla"))]
    {
        use dials::influence::{train_aip_fused, FusedAipAgent, InfluenceDataset};
        use dials::nn::NetState;
        use dials::runtime::{synth, ArtifactSet, NetSpec};

        fn build_dataset(
            spec: &NetSpec,
            n_eps: usize,
            ep_len: usize,
            rng: &mut Pcg64,
        ) -> InfluenceDataset {
            let mut ds = InfluenceDataset::new(spec.aip_feat, spec.aip_heads, n_eps * ep_len);
            let classes = if spec.aip_recurrent { spec.aip_cls as u64 } else { 2 };
            let mut feat = vec![0.0f32; spec.aip_feat];
            let mut label = vec![0.0f32; spec.aip_heads];
            for _ in 0..n_eps {
                ds.begin_episode();
                for _ in 0..ep_len {
                    for f in feat.iter_mut() {
                        *f = 0.5 * rng.normal() as f32;
                    }
                    for l in label.iter_mut() {
                        *l = rng.below(classes) as f32;
                    }
                    ds.push(&feat, &label);
                }
            }
            ds
        }

        let n = 16usize;
        let epochs = 8usize;
        for domain in [Domain::Traffic, Domain::Warehouse] {
            let dir = std::env::temp_dir()
                .join("dials_hotpath_synth")
                .join(format!("aip_retrain_{}", domain.name()));
            let _ = std::fs::remove_dir_all(&dir);
            synth::write_native_artifacts(&dir, domain, 3)?;
            let arts = ArtifactSet::load(&engine, &dir, domain)?;
            let spec = &arts.spec;
            let ep_len = spec.aip_seq.max(1) + 4;
            let mut root = Pcg64::new(23, 4242);
            let mut datasets = Vec::new();
            let mut nets = Vec::new();
            for i in 0..n {
                let mut rng = root.split(i as u64 + 1);
                nets.push(NetState::jittered(&arts.aip_init, &mut rng, 0.02));
                datasets.push(build_dataset(spec, 8, ep_len, &mut rng));
            }
            for (label, fused) in [("fused", true), ("per-agent", false)] {
                let mut my_nets = nets.clone();
                let mut rngs: Vec<Pcg64> =
                    (0..n).map(|i| Pcg64::new(29, i as u64)).collect();
                let mut retrain = |nets: &mut [NetState], rngs: &mut [Pcg64]| {
                    if fused {
                        let mut agents: Vec<FusedAipAgent<'_>> = nets
                            .iter_mut()
                            .zip(rngs.iter_mut())
                            .zip(datasets.iter())
                            .map(|((net, rng), dataset)| FusedAipAgent { net, dataset, rng })
                            .collect();
                        train_aip_fused(&arts, &mut agents, epochs).unwrap();
                    } else {
                        for ((net, rng), dataset) in
                            nets.iter_mut().zip(rngs.iter_mut()).zip(datasets.iter())
                        {
                            dataset.train(&arts, net, epochs, rng).unwrap();
                        }
                    }
                };
                // warm-up: bank/device-slot allocation and scratch sizing
                retrain(&mut my_nets, &mut rngs);
                let (mean, min) = time_n(3, || retrain(&mut my_nets, &mut rngs));
                push_row_aip(
                    &mut table, &mut json,
                    &format!(
                        "{} AIP retrain x{epochs} epochs ({label}, N={n})",
                        domain.name()
                    ),
                    mean, min, "1 retrain", mean,
                );
            }
        }
    }

    // ---- dials serve: dynamic-batching inference over a policy bank
    //
    // End-to-end request latency of the serve tick loop under the
    // built-in GS load generator, native backend. N = 1 (grid side 1) so
    // S streams are S independent single-agent GS instances — the purest
    // view of batching: S = 1 is the serial floor, S = 64 shows how far
    // one batched `run_b` per tick amortizes the forward. The p50/p99
    // columns land in BENCH_hotpath.json as `serve_p50_us`/`serve_p99_us`
    // and are growth-gated by tools/bench_diff.
    #[cfg(not(feature = "xla"))]
    {
        use dials::runtime::synth;
        use dials::serve::{run_load_gen, Batcher, LoadGenOpts, PolicyStore, ServeOpts};

        let domain = Domain::Traffic;
        let dir = std::env::temp_dir().join("dials_hotpath_synth").join("serve");
        let _ = std::fs::remove_dir_all(&dir);
        synth::write_native_artifacts(&dir, domain, 3)?;
        let cfg = ExperimentConfig {
            domain,
            mode: SimMode::Dials,
            grid_side: 1,
            total_steps: 64,
            aip_train_freq: 32,
            aip_epochs: 0,
            eval_every: 32,
            horizon: 100,
            seed: 7,
            ppo: PpoConfig { rollout_len: 256, minibatch: 32, epochs: 1, ..Default::default() },
            artifacts_dir: dir.to_string_lossy().into_owned(),
            ..Default::default()
        };
        let coord = DialsCoordinator::new(&engine, cfg)?;
        let arts = coord.artifacts();
        let nets: Vec<_> = coord.make_workers(7).iter().map(|w| w.policy.net.clone()).collect();
        const TOTAL_REQS: usize = 2000;
        for streams in [1usize, 8, 64] {
            let opts = ServeOpts {
                streams,
                max_batch: streams,
                seed: 7,
                ..Default::default()
            };
            let mut batcher = Batcher::new(arts, PolicyStore::from_nets(nets.clone()), &opts)?;
            let lg = LoadGenOpts {
                domain,
                grid_side: 1,
                steps_per_stream: TOTAL_REQS / streams,
                horizon: 100,
                seed: 7,
            };
            let stats = run_load_gen(arts, &mut batcher, None, &opts, &lg)?;
            let mean_s = stats.e2e.mean_us() * 1e-6;
            let rps = stats.requests as f64 / stats.wall_seconds;
            push_row_serve(
                &mut table, &mut json,
                &format!("serve e2e S={streams} (N=1)"),
                mean_s, mean_s, "1 request", rps,
                stats.e2e.p50_us(), stats.e2e.p99_us(),
            );
        }
    }

    table.print();
    table.save_csv("hotpath");
    write_json(&json, sim_zero_alloc)?;
    println!(
        "\nsim-layer zero-alloc check: {}",
        if sim_zero_alloc { "PASS (0 B/step across GS+LS loops)" } else { "FAIL" }
    );
    if !sim_zero_alloc {
        std::process::exit(1);
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn push_row(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    bytes_per_step: f64,
    peak_extra: usize,
    calls_per_step: f64,
) {
    push_row_steps(table, json, op, mean, min, unit, bytes_per_step, peak_extra, calls_per_step, f64::NAN);
}

/// `push_row` plus the GS-phase steps/s column (for GS stepping rows).
#[allow(clippy::too_many_arguments)]
fn push_row_steps(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    bytes_per_step: f64,
    peak_extra: usize,
    calls_per_step: f64,
    steps_per_s: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, bytes_per_step, peak_extra, calls_per_step,
        steps_per_s, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
        f64::NAN,
    );
}

/// `push_row` for the megabatch LS training rows: per-tick timing plus
/// the replica-summed `ls_steps_per_s` throughput column.
#[allow(clippy::too_many_arguments)]
fn push_row_ls(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    calls_per_step: f64,
    ls_steps_per_s: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, f64::NAN, 0, calls_per_step, f64::NAN,
        ls_steps_per_s, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
    );
}

/// `push_row` for the fused-update megabatch training rows: per-tick
/// timing, heap bytes per PPO update, replica-summed throughput, and the
/// gated update-wall column (seconds inside the fill-tick update phases
/// per measured segment).
#[allow(clippy::too_many_arguments)]
fn push_row_update(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    bytes_per_update: f64,
    peak_extra: usize,
    ls_steps_per_s: f64,
    update_wall_s: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, bytes_per_update, peak_extra, f64::NAN, f64::NAN,
        ls_steps_per_s, update_wall_s, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
        f64::NAN,
    );
}

/// `push_row` for the blocking-vs-async collect coordinator rows: the
/// collect-wall column carries the run's on-path influence seconds.
fn push_row_collect(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    collect_wall_s: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, f64::NAN, 0, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
        f64::NAN, collect_wall_s, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
    );
}

/// `push_row` for the fused-vs-per-agent AIP retrain rows: the aip-wall
/// column carries the wall seconds of one whole-system retrain.
fn push_row_aip(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    aip_update_wall_s: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, f64::NAN, 0, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
        f64::NAN, f64::NAN, aip_update_wall_s, f64::NAN, f64::NAN, f64::NAN,
    );
}

/// `push_row` for the `dials serve` load-gen rows: per-request e2e mean
/// plus the gated latency percentile columns.
#[allow(clippy::too_many_arguments)]
fn push_row_serve(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    steps_per_s: f64,
    serve_p50_us: f64,
    serve_p99_us: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, f64::NAN, 0, f64::NAN, steps_per_s, f64::NAN,
        f64::NAN, f64::NAN, f64::NAN, f64::NAN, serve_p50_us, serve_p99_us, f64::NAN,
    );
}

/// `push_row` for the multi-process `DistPlan` loopback rows: the gated
/// `dist steps/s` column carries joint GS steps per second through the
/// process-boundary protocol.
fn push_row_dist(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    dist_steps_per_s: f64,
) {
    push_row_full(
        table, json, op, mean, min, unit, f64::NAN, 0, f64::NAN, f64::NAN, f64::NAN, f64::NAN,
        f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, dist_steps_per_s,
    );
}

/// The full row shape, including the segment+eval and collect wall-clock
/// columns the blocking-vs-async coordinator rows report.
#[allow(clippy::too_many_arguments)]
fn push_row_full(
    table: &mut Table,
    json: &mut Vec<JsonRow>,
    op: &str,
    mean: f64,
    min: f64,
    unit: &str,
    bytes_per_step: f64,
    peak_extra: usize,
    calls_per_step: f64,
    steps_per_s: f64,
    ls_steps_per_s: f64,
    update_wall_s: f64,
    seg_eval_wall_s: f64,
    collect_wall_s: f64,
    aip_update_wall_s: f64,
    serve_p50_us: f64,
    serve_p99_us: f64,
    dist_steps_per_s: f64,
) {
    let bps = if bytes_per_step.is_nan() { "-".to_string() } else { format!("{bytes_per_step:.1}") };
    let cps = if calls_per_step.is_nan() { "-".to_string() } else { format!("{calls_per_step:.2}") };
    let sps = if steps_per_s.is_nan() { "-".to_string() } else { format!("{steps_per_s:.0}") };
    let lsps = if ls_steps_per_s.is_nan() { "-".to_string() } else { format!("{ls_steps_per_s:.0}") };
    let uwall = if update_wall_s.is_nan() { "-".to_string() } else { format!("{update_wall_s:.3}s") };
    let wall = if seg_eval_wall_s.is_nan() { "-".to_string() } else { format!("{seg_eval_wall_s:.3}s") };
    let cwall = if collect_wall_s.is_nan() { "-".to_string() } else { format!("{collect_wall_s:.3}s") };
    let awall = if aip_update_wall_s.is_nan() { "-".to_string() } else { format!("{aip_update_wall_s:.3}s") };
    let p50 = if serve_p50_us.is_nan() { "-".to_string() } else { format!("{serve_p50_us:.1}us") };
    let p99 = if serve_p99_us.is_nan() { "-".to_string() } else { format!("{serve_p99_us:.1}us") };
    let dsps = if dist_steps_per_s.is_nan() { "-".to_string() } else { format!("{dist_steps_per_s:.0}") };
    table.row(vec![
        op.to_string(),
        us(mean),
        us(min),
        unit.to_string(),
        bps,
        format!("{peak_extra}B"),
        cps,
        sps,
        lsps,
        uwall,
        wall,
        cwall,
        awall,
        p50,
        p99,
        dsps,
    ]);
    json.push(JsonRow {
        op: op.to_string(),
        mean_s: mean,
        min_s: min,
        bytes_per_step,
        peak_extra_bytes: peak_extra,
        calls_per_step,
        steps_per_s,
        ls_steps_per_s,
        update_wall_s,
        seg_eval_wall_s,
        collect_wall_s,
        aip_update_wall_s,
        serve_p50_us,
        serve_p99_us,
        dist_steps_per_s,
    });
}

/// Hand-rolled JSON (the offline vendor ships no serde).
fn write_json(rows: &[JsonRow], sim_zero_alloc: bool) -> Result<()> {
    let mut s = String::from("{\n  \"bench\": \"hotpath\",\n  \"rows\": [\n");
    for (k, r) in rows.iter().enumerate() {
        let bps = if r.bytes_per_step.is_nan() { "null".to_string() } else { format!("{:.3}", r.bytes_per_step) };
        let cps = if r.calls_per_step.is_nan() { "null".to_string() } else { format!("{:.3}", r.calls_per_step) };
        let sps = if r.steps_per_s.is_nan() { "null".to_string() } else { format!("{:.1}", r.steps_per_s) };
        let lsps = if r.ls_steps_per_s.is_nan() { "null".to_string() } else { format!("{:.1}", r.ls_steps_per_s) };
        let uwall = if r.update_wall_s.is_nan() { "null".to_string() } else { format!("{:.6}", r.update_wall_s) };
        let wall = if r.seg_eval_wall_s.is_nan() { "null".to_string() } else { format!("{:.6}", r.seg_eval_wall_s) };
        let cwall = if r.collect_wall_s.is_nan() { "null".to_string() } else { format!("{:.6}", r.collect_wall_s) };
        let awall = if r.aip_update_wall_s.is_nan() { "null".to_string() } else { format!("{:.6}", r.aip_update_wall_s) };
        let p50 = if r.serve_p50_us.is_nan() { "null".to_string() } else { format!("{:.3}", r.serve_p50_us) };
        let p99 = if r.serve_p99_us.is_nan() { "null".to_string() } else { format!("{:.3}", r.serve_p99_us) };
        let dsps = if r.dist_steps_per_s.is_nan() { "null".to_string() } else { format!("{:.1}", r.dist_steps_per_s) };
        s.push_str(&format!(
            "    {{\"op\": {:?}, \"mean_s\": {:.9}, \"min_s\": {:.9}, \"bytes_per_step\": {}, \"peak_extra_bytes\": {}, \"calls_per_step\": {}, \"steps_per_s\": {}, \"ls_steps_per_s\": {}, \"update_wall_s\": {}, \"seg_eval_wall_s\": {}, \"collect_wall_s\": {}, \"aip_update_wall_s\": {}, \"serve_p50_us\": {}, \"serve_p99_us\": {}, \"dist_steps_per_s\": {}}}{}\n",
            r.op, r.mean_s, r.min_s, bps, r.peak_extra_bytes, cps, sps, lsps, uwall, wall, cwall, awall, p50, p99, dsps,
            if k + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str(&format!("  ],\n  \"sim_zero_alloc\": {sim_zero_alloc}\n}}\n"));
    std::fs::write("BENCH_hotpath.json", &s)?;
    eprintln!("[bench] wrote BENCH_hotpath.json");
    Ok(())
}

fn us(secs: f64) -> String {
    if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}
