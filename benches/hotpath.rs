//! Hot-path microbenchmarks (§Perf deliverable, not a paper table).
//!
//! Measures every component on the per-step critical path so the perf pass
//! can attribute time: simulator steps, PJRT executable invocations
//! (policy forward, AIP forward), the PPO/AIP update calls, and the
//! end-to-end per-agent step of the IALS training loop.
//!
//!     cargo bench --offline --bench hotpath

use anyhow::Result;

use dials::config::{Domain, ExperimentConfig, PpoConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::ppo::PpoTrainer;
use dials::runtime::Engine;
use dials::sim::{traffic::TrafficGlobalSim, warehouse::WarehouseGlobalSim, GlobalSim, LocalSim};
use dials::sim::traffic::TrafficLocalSim;
use dials::sim::warehouse::WarehouseLocalSim;
use dials::util::bench::{time_n, Table};
use dials::util::npk::Tensor;
use dials::util::rng::Pcg64;

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let mut table = Table::new("hot path microbenchmarks", &["op", "mean", "min", "per-unit"]);
    let reps = 200;

    // ---- simulators
    {
        let mut rng = Pcg64::seed(0);
        let mut ls = TrafficLocalSim::new();
        ls.reset(&mut rng);
        let (mean, min) = time_n(reps, || {
            ls.step(0, &[1.0, 0.0, 0.0, 0.0], &mut rng);
        });
        table.row(vec!["traffic LS step".into(), us(mean), us(min), "1 step".into()]);

        let mut wls = WarehouseLocalSim::new();
        wls.reset(&mut rng);
        let (mean, min) = time_n(reps, || {
            wls.step(1, &[3.0, 3.0, 3.0, 3.0], &mut rng);
        });
        table.row(vec!["warehouse LS step".into(), us(mean), us(min), "1 step".into()]);

        let mut gs = TrafficGlobalSim::new(5);
        gs.reset(&mut rng);
        let acts = vec![0usize; 25];
        let (mean, min) = time_n(reps, || {
            gs.step(&acts, &mut rng);
        });
        table.row(vec!["traffic GS step (25 ints)".into(), us(mean), us(min), "25 agents".into()]);

        let mut wgs = WarehouseGlobalSim::new(5);
        wgs.reset(&mut rng);
        let (mean, min) = time_n(reps, || {
            wgs.step(&acts, &mut rng);
        });
        table.row(vec!["warehouse GS step (25 rb)".into(), us(mean), us(min), "25 agents".into()]);
    }

    // ---- PJRT executable calls
    for domain in [Domain::Traffic, Domain::Warehouse] {
        let cfg = ExperimentConfig {
            domain,
            mode: SimMode::Dials,
            ppo: PpoConfig::default(),
            ..Default::default()
        };
        let coord = DialsCoordinator::new(&engine, cfg.clone())?;
        let arts = coord.artifacts();
        let spec = &arts.spec;
        let params = arts.policy_init.clone();
        let obs = Tensor::zeros(&[1, spec.obs_dim]);
        let h = Tensor::zeros(&[1, spec.policy_hstate]);
        let (mean, min) = time_n(reps, || {
            arts.policy_step.run(&[params.clone(), obs.clone(), h.clone()]).unwrap();
        });
        table.row(vec![format!("{} policy_step HLO call", domain.name()), us(mean), us(min), "1 fwd".into()]);

        let ap = arts.aip_init.clone();
        let feat = Tensor::zeros(&[1, spec.aip_feat]);
        let ah = Tensor::zeros(&[1, spec.aip_hstate]);
        let (mean, min) = time_n(reps, || {
            arts.aip_forward.run(&[ap.clone(), feat.clone(), ah.clone()]).unwrap();
        });
        table.row(vec![format!("{} aip_forward HLO call", domain.name()), us(mean), us(min), "1 fwd".into()]);

        // full PPO update (epochs × minibatches over one rollout)
        let mut workers = coord.make_workers(0);
        let w = &mut workers[0];
        let trainer = PpoTrainer::new(cfg.ppo.clone());
        // fill one rollout via real stepping
        w.train_segment(arts, &trainer, cfg.ppo.rollout_len, cfg.horizon)?;
        let mut rng = Pcg64::seed(1);
        // measure the raw update call on a synthetic full buffer
        let mut buf = dials::ppo::RolloutBuffer::new(cfg.ppo.rollout_len, spec.obs_dim, spec.policy_hstate);
        let obs_row = vec![0.1f32; spec.obs_dim];
        let h_row = vec![0.0f32; spec.policy_hstate];
        for t in 0..cfg.ppo.rollout_len {
            buf.push(&obs_row, &h_row, t % spec.act_dim, -0.5, 0.3, 0.2, t % cfg.horizon == cfg.horizon - 1);
        }
        let (mean, min) = time_n(20, || {
            trainer.update(arts, &mut w.policy.net, &buf, 0.0, &mut rng).unwrap();
        });
        let calls = cfg.ppo.epochs * (cfg.ppo.rollout_len / cfg.ppo.minibatch);
        table.row(vec![
            format!("{} PPO update (rollout)", domain.name()),
            us(mean), us(min), format!("{calls} HLO calls"),
        ]);

        // end-to-end IALS training step
        let (mean, min) = time_n(20, || {
            w.train_segment(arts, &trainer, 32, cfg.horizon).unwrap();
        });
        table.row(vec![
            format!("{} IALS train step e2e", domain.name()),
            us(mean / 32.0), us(min / 32.0), "per env step".into(),
        ]);
    }

    table.print();
    table.save_csv("hotpath");
    Ok(())
}

fn us(secs: f64) -> String {
    if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}
