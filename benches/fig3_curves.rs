//! Fig. 3 (1a)/(1b): learning curves of GS vs DIALS vs untrained-DIALS on
//! the 4-agent traffic and warehouse environments, averaged over seeds.
//!
//! Paper shape to reproduce: DIALS converges steadily to high returns;
//! untrained-DIALS plateaus below it (influence estimation matters); GS is
//! noisier/worse due to simultaneous-learning non-stationarity.
//!
//!     cargo bench --offline --bench fig3_curves
//!     cargo bench --offline --bench fig3_curves -- --steps 8000 --seeds 5

use anyhow::Result;

use dials::baselines::{scripted_return, GsTrainer};
use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::Table;
use dials::util::cli::Args;
use dials::util::metrics::aggregate_curves;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let steps = args.get_usize("steps", 3000)?;
    let n_seeds = args.get_usize("seeds", 3)?;
    let engine = Engine::cpu()?;

    for domain in [Domain::Traffic, Domain::Warehouse] {
        // the warehouse's sparse age-ranked rewards need a longer budget
        // for the AIP effect to show (paper trains for 4M steps)
        let steps = if domain == Domain::Warehouse { steps * 2 } else { steps };
        let mut table = Table::new(
            &format!("Fig3 curves — {} (4 agents, {} steps, {} seeds)", domain.name(), steps, n_seeds),
            &["step", "GS", "GS ±", "DIALS", "DIALS ±", "untrained", "untr ±"],
        );
        let mut all: Vec<Vec<(usize, f64, f64)>> = Vec::new();
        for mode in [SimMode::GlobalSim, SimMode::Dials, SimMode::UntrainedDials] {
            let mut curves = Vec::new();
            for seed in 0..n_seeds as u64 {
                let cfg = ExperimentConfig {
                    domain,
                    mode,
                    grid_side: 2,
                    total_steps: steps,
                    aip_train_freq: (steps / 4).max(1),
                    aip_dataset: 600,
                    aip_epochs: 30,
                    eval_every: (steps / 6).max(1),
                    eval_episodes: 2,
                    horizon: 100,
                    seed,
                    ..Default::default()
                };
                let coord = DialsCoordinator::new(&engine, cfg)?;
                let log = match mode {
                    SimMode::GlobalSim => GsTrainer::new(coord).run()?,
                    _ => coord.run()?,
                };
                curves.push(log.eval_curve);
            }
            all.push(aggregate_curves(&curves));
        }
        let n_points = all.iter().map(|c| c.len()).min().unwrap_or(0);
        for i in 0..n_points {
            table.row(vec![
                format!("{}", all[0][i].0),
                format!("{:.3}", all[0][i].1),
                format!("{:.3}", all[0][i].2),
                format!("{:.3}", all[1][i].1),
                format!("{:.3}", all[1][i].2),
                format!("{:.3}", all[2][i].1),
                format!("{:.3}", all[2][i].2),
            ]);
        }
        table.print();
        table.save_csv(&format!("fig3_curves_{}", domain.name()));
        let scripted = scripted_return(domain, 2, 5, 100, 0);
        println!("hand-coded baseline (dashed line): {scripted:.3}");

        // paper-shape assertion: DIALS(final) >= untrained-DIALS(final)
        let d_final = all[1].last().map(|p| p.1).unwrap_or(0.0);
        let u_final = all[2].last().map(|p| p.1).unwrap_or(0.0);
        println!(
            "shape check [{}]: DIALS {:.3} vs untrained {:.3} -> {}",
            domain.name(), d_final, u_final,
            if d_final >= u_final { "OK" } else { "NOT reproduced at this budget" }
        );
    }
    Ok(())
}
