//! Fig. 4 (and appendix Fig. 7/8): the AIP-training-frequency sweep.
//! Left panels: learning curves for F ∈ {total/8, total/4, total/2, total};
//! right panels: the AIPs' cross-entropy on fresh GS trajectories.
//!
//! Paper shape to reproduce: traffic benefits from periodic retraining
//! (too-stale AIPs hurt), while in the warehouse training ONCE suffices —
//! and retraining too often is detrimental (§4.3). CE drops at every
//! retrain point.
//!
//!     cargo bench --offline --bench fig4_freq
//!     cargo bench --offline --bench fig4_freq -- --grid-side 5 --steps 4000
//!     cargo bench --offline --bench fig4_freq -- --ablation independent

use anyhow::Result;

use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::{fmt_secs, Table};
use dials::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let steps = args.get_usize("steps", 2400)?;
    let side = args.get_usize("grid-side", 3)?;
    let engine = Engine::cpu()?;

    if args.get_or("ablation", "") == "independent" {
        return corollary1_ablation(&engine, steps);
    }

    for domain in [Domain::Traffic, Domain::Warehouse] {
        let mut table = Table::new(
            &format!("Fig4 — {} ({} agents, {} steps): F sweep", domain.name(), side * side, steps),
            &["F", "final return", "CE first", "CE last", "data+AIP time", "total (CP)"],
        );
        for divisor in [8usize, 4, 2, 1] {
            let f = (steps / divisor).max(1);
            let cfg = ExperimentConfig {
                domain,
                mode: SimMode::Dials,
                grid_side: side,
                total_steps: steps,
                aip_train_freq: f,
                aip_dataset: 400,
                aip_epochs: 25,
                eval_every: (steps / 4).max(1),
                eval_episodes: 2,
                horizon: 100,
                seed: 0,
                ..Default::default()
            };
            let coord = DialsCoordinator::new(&engine, cfg)?;
            let log = coord.run()?;
            let ce_first = log.ce_curve.first().map(|p| p.value).unwrap_or(f64::NAN);
            let ce_last = log.ce_curve.last().map(|p| p.value).unwrap_or(f64::NAN);
            table.row(vec![
                format!("{f}"),
                format!("{:.3}", log.final_return),
                format!("{ce_first:.4}"),
                format!("{ce_last:.4}"),
                fmt_secs(log.influence_seconds),
                fmt_secs(log.critical_path_seconds),
            ]);
            println!(
                "[{} F={f}] CE trace: {}",
                domain.name(),
                log.ce_curve.iter().map(|p| format!("{:.3}", p.value)).collect::<Vec<_>>().join(" ")
            );
        }
        table.print();
        table.save_csv(&format!("fig4_freq_{}", domain.name()));
    }
    Ok(())
}

/// Corollary 1 ablation: with influence-independent local regions, a
/// once-trained AIP stays accurate no matter how the other agents' policies
/// change. The traffic boundary lanes of a 1×1 grid are exactly this case
/// (inflows are policy-independent Bernoulli sources): the CE of F=total
/// must match the CE of frequent retraining.
fn corollary1_ablation(engine: &Engine, steps: usize) -> Result<()> {
    let mut table = Table::new(
        "Corollary 1 ablation — 1×1 traffic (policy-independent influences)",
        &["F", "CE first", "CE last", "drift"],
    );
    for divisor in [4usize, 1] {
        let f = (steps / divisor).max(1);
        let cfg = ExperimentConfig {
            domain: Domain::Traffic,
            mode: SimMode::Dials,
            grid_side: 1,
            total_steps: steps,
            aip_train_freq: f,
            aip_dataset: 500,
            aip_epochs: 40,
            eval_every: steps,
            eval_episodes: 2,
            horizon: 100,
            seed: 0,
            ..Default::default()
        };
        let coord = DialsCoordinator::new(engine, cfg)?;
        let log = coord.run()?;
        let first = log.ce_curve.iter().skip(1).map(|p| p.value).next().unwrap_or(f64::NAN);
        let last = log.ce_curve.last().map(|p| p.value).unwrap_or(f64::NAN);
        table.row(vec![
            format!("{f}"),
            format!("{first:.4}"),
            format!("{last:.4}"),
            format!("{:+.4}", last - first),
        ]);
    }
    table.print();
    table.save_csv("corollary1_ablation");
    println!("expected: near-zero drift for BOTH rows (unique influence distribution)");
    Ok(())
}
