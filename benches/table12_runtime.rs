//! Tables 1 & 2 (App. G): runtime breakdown — "agents training" vs "data
//! collection + influence training" vs total — for GS, DIALS at several F,
//! and untrained-DIALS, across agent counts.
//!
//! Paper shape to reproduce (per domain):
//!   * GS total grows steeply with N; DIALS agent-training stays ~flat
//!     (critical-path model on this box, see DESIGN.md);
//!   * the influence column scales with N (data collection is the GS) and
//!     inversely with F — exactly the paper's gap between DIALS F=100K
//!     and F=4M;
//!   * untrained-DIALS has zero influence cost.
//!
//!     cargo bench --offline --bench table12_runtime
//!     cargo bench --offline --bench table12_runtime -- --sizes 2,5,7 --steps 1500

use anyhow::Result;

use dials::baselines::GsTrainer;
use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::{fmt_secs, Table};
use dials::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let steps = args.get_usize("steps", 1000)?;
    let sizes = args.get_usize_list("sizes", &[2, 5])?;
    let engine = Engine::cpu()?;

    for domain in [Domain::Traffic, Domain::Warehouse] {
        let tbl_no = if domain == Domain::Traffic { 1 } else { 2 };
        let mut table = Table::new(
            &format!("Table {tbl_no} — {} runtimes ({} steps/agent; CP model)", domain.name(), steps),
            &["condition", "agents", "agents training", "data+influence", "total"],
        );
        for &side in &sizes {
            let n = side * side;
            // GS row
            let gs_log = {
                let cfg = base_cfg(domain, side, steps, steps, SimMode::GlobalSim);
                GsTrainer::new(DialsCoordinator::new(&engine, cfg)?).run()?
            };
            table.row(vec![
                "GS".into(), format!("{n}"),
                fmt_secs(gs_log.agent_train_seconds), "-".into(),
                fmt_secs(gs_log.critical_path_seconds),
            ]);
            // DIALS rows at several F (paper: F=100K..4M of 4M)
            for divisor in [8usize, 4, 2, 1] {
                let f = (steps / divisor).max(1);
                let cfg = base_cfg(domain, side, steps, f, SimMode::Dials);
                let log = DialsCoordinator::new(&engine, cfg)?.run()?;
                table.row(vec![
                    format!("DIALS F={f}"), format!("{n}"),
                    fmt_secs(log.agent_train_seconds),
                    fmt_secs(log.influence_seconds),
                    fmt_secs(log.critical_path_seconds),
                ]);
            }
            // untrained row
            let cfg = base_cfg(domain, side, steps, steps, SimMode::UntrainedDials);
            let log = DialsCoordinator::new(&engine, cfg)?.run()?;
            table.row(vec![
                "untrained-DIALS".into(), format!("{n}"),
                fmt_secs(log.agent_train_seconds), "-".into(),
                fmt_secs(log.critical_path_seconds),
            ]);
        }
        table.print();
        table.save_csv(&format!("table{tbl_no}_runtime_{}", domain.name()));
    }
    Ok(())
}

fn base_cfg(domain: Domain, side: usize, steps: usize, f: usize, mode: SimMode) -> ExperimentConfig {
    ExperimentConfig {
        domain,
        mode,
        grid_side: side,
        total_steps: steps,
        aip_train_freq: f,
        aip_dataset: 300,
        aip_epochs: 20,
        eval_every: steps,
        eval_episodes: 1,
        horizon: 100,
        seed: 0,
        ..Default::default()
    }
}
