//! `bench_diff` — the CI bench-regression gate.
//!
//! Diffs a freshly produced `BENCH_hotpath.json` against the committed
//! baseline (`benches/BENCH_baseline.json`) and exits non-zero when the
//! hot path regressed:
//!
//! * `calls_per_step` — the batch-first contract (`run_b` executions per
//!   joint GS step) may NEVER grow: any increase fails the gate;
//! * `bytes_per_step` — heap traffic per step may never grow either (the
//!   zero-alloc rows gate at exactly 0);
//! * `steps_per_s` — throughput may drop at most 20% below the baseline
//!   (timing noise tolerance; the structural metrics above are exact);
//! * `ls_steps_per_s` — megabatch LS training throughput (trained env
//!   steps per second across all replicas) gets the same 20% tolerance;
//! * `dist_steps_per_s` — joint GS throughput through the multi-process
//!   `DistPlan` loopback protocol gets the same 20% tolerance;
//! * `seg_eval_wall_s` / `collect_wall_s` — the overlap wall-clock of the
//!   blocking-vs-async coordinator rows may grow at most 25% above the
//!   baseline, so the segment+eval and segment+collect overlaps stay
//!   regression-gated once the baseline records CI-measured values;
//! * `update_wall_s` — the PPO update share of a megabatch training
//!   segment's wall (the fused-vs-per-agent update rows) gets the same
//!   25% growth tolerance, keeping the fused-update win gated;
//! * `aip_update_wall_s` — the wall seconds of one whole-system AIP
//!   retrain (the fused-vs-per-agent retrain rows) gets the same 25%
//!   growth tolerance, keeping the fused influence retrain gated;
//! * `serve_p50_us` / `serve_p99_us` — the `dials serve` end-to-end
//!   request latency percentiles of the serve load-gen rows get the same
//!   25% growth tolerance (latency, so growth is the regression);
//! * `sim_zero_alloc` — the bench's own hard gate must still be true.
//!
//! Rows are matched by their `op` string. A baseline metric of `null`
//! means "not gated yet" (machine-dependent until a baseline refresh);
//! baseline rows missing from the fresh run only warn, because some rows
//! embed machine facts (thread counts) in their names. At least
//! `MIN_MATCHED` rows must match so a renamed bench cannot silently
//! disable the gate.
//!
//! Refreshing the baseline (see DESIGN.md §9): download the
//! `BENCH_hotpath` artifact from a green CI run on main and commit it as
//! `benches/BENCH_baseline.json` — never regenerate it on a laptop, the
//! throughput floors are only meaningful on the CI machine class.
//!
//!     cargo run --release --bin bench_diff -- BENCH_hotpath.json benches/BENCH_baseline.json

use std::collections::BTreeMap;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

/// Minimum matched rows for the gate to count as armed.
const MIN_MATCHED: usize = 5;
/// Allowed fractional drop in `steps_per_s` (0.20 = 20%).
const STEPS_DROP_TOL: f64 = 0.20;
/// Allowed fractional growth of the overlap wall-clock columns
/// (`seg_eval_wall_s`, `collect_wall_s`).
const WALL_GROW_TOL: f64 = 0.25;
/// Slack for the "may never grow" metrics (float formatting noise only).
const EPS: f64 = 1e-6;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (fresh, baseline) = match args.as_slice() {
        [f, b] => (f.clone(), b.clone()),
        _ => {
            eprintln!("usage: bench_diff <fresh BENCH_hotpath.json> <baseline json>");
            return ExitCode::from(2);
        }
    };
    match run_diff(&fresh, &baseline) {
        Ok(regressions) if regressions.is_empty() => {
            println!("bench gate: PASS");
            ExitCode::SUCCESS
        }
        Ok(regressions) => {
            for r in &regressions {
                eprintln!("REGRESSION: {r}");
            }
            eprintln!("bench gate: FAIL ({} regression(s))", regressions.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("bench gate error: {e:#}");
            ExitCode::from(2)
        }
    }
}

fn run_diff(fresh_path: &str, baseline_path: &str) -> Result<Vec<String>> {
    let fresh = std::fs::read_to_string(fresh_path)
        .with_context(|| format!("read fresh bench json {fresh_path}"))?;
    let baseline = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("read baseline json {baseline_path}"))?;
    diff(&fresh, &baseline)
}

/// Compare two bench JSON documents; returns the list of regressions.
fn diff(fresh: &str, baseline: &str) -> Result<Vec<String>> {
    let fresh = Bench::parse(fresh).context("parse fresh bench json")?;
    let base = Bench::parse(baseline).context("parse baseline json")?;
    let mut regressions = Vec::new();

    if !fresh.sim_zero_alloc {
        regressions.push("sim_zero_alloc is false: a simulator step loop allocates".to_string());
    }

    let mut matched = 0usize;
    for (op, b) in &base.rows {
        let Some(f) = fresh.rows.get(op) else {
            eprintln!("warn: baseline row {op:?} missing from fresh run (machine-dependent?)");
            continue;
        };
        matched += 1;
        // Fail closed: a metric the baseline gates must exist in the fresh
        // run — a row that stops reporting it would otherwise disarm the
        // gate as effectively as a regression.
        if let Some(bv) = b.calls_per_step {
            match f.calls_per_step {
                Some(fv) if fv > bv + EPS => regressions.push(format!(
                    "{op}: calls_per_step grew {bv:.3} -> {fv:.3} (must never grow)"
                )),
                Some(_) => {}
                None => regressions.push(format!(
                    "{op}: gated calls_per_step missing (null) in fresh run"
                )),
            }
        }
        if let Some(bv) = b.bytes_per_step {
            match f.bytes_per_step {
                Some(fv) if fv > bv + EPS => regressions.push(format!(
                    "{op}: bytes_per_step grew {bv:.3} -> {fv:.3} (must never grow)"
                )),
                Some(_) => {}
                None => regressions.push(format!(
                    "{op}: gated bytes_per_step missing (null) in fresh run"
                )),
            }
        }
        if let Some(bv) = b.steps_per_s {
            match f.steps_per_s {
                Some(fv) if fv < bv * (1.0 - STEPS_DROP_TOL) => regressions.push(format!(
                    "{op}: steps_per_s dropped {bv:.1} -> {fv:.1} (>{:.0}% below baseline)",
                    STEPS_DROP_TOL * 100.0
                )),
                Some(_) => {}
                None => regressions.push(format!(
                    "{op}: gated steps_per_s missing (null) in fresh run"
                )),
            }
        }
        if let Some(bv) = b.ls_steps_per_s {
            match f.ls_steps_per_s {
                Some(fv) if fv < bv * (1.0 - STEPS_DROP_TOL) => regressions.push(format!(
                    "{op}: ls_steps_per_s dropped {bv:.1} -> {fv:.1} (>{:.0}% below baseline)",
                    STEPS_DROP_TOL * 100.0
                )),
                Some(_) => {}
                None => regressions.push(format!(
                    "{op}: gated ls_steps_per_s missing (null) in fresh run"
                )),
            }
        }
        if let Some(bv) = b.dist_steps_per_s {
            match f.dist_steps_per_s {
                Some(fv) if fv < bv * (1.0 - STEPS_DROP_TOL) => regressions.push(format!(
                    "{op}: dist_steps_per_s dropped {bv:.1} -> {fv:.1} (>{:.0}% below baseline)",
                    STEPS_DROP_TOL * 100.0
                )),
                Some(_) => {}
                None => regressions.push(format!(
                    "{op}: gated dist_steps_per_s missing (null) in fresh run"
                )),
            }
        }
        for (metric, unit, bval, fval) in [
            ("seg_eval_wall_s", "s", b.seg_eval_wall_s, f.seg_eval_wall_s),
            ("collect_wall_s", "s", b.collect_wall_s, f.collect_wall_s),
            ("update_wall_s", "s", b.update_wall_s, f.update_wall_s),
            ("aip_update_wall_s", "s", b.aip_update_wall_s, f.aip_update_wall_s),
            ("serve_p50_us", "us", b.serve_p50_us, f.serve_p50_us),
            ("serve_p99_us", "us", b.serve_p99_us, f.serve_p99_us),
        ] {
            let Some(bv) = bval else { continue };
            match fval {
                Some(fv) if fv > bv * (1.0 + WALL_GROW_TOL) => regressions.push(format!(
                    "{op}: {metric} grew {bv:.3}{unit} -> {fv:.3}{unit} (>{:.0}% above baseline)",
                    WALL_GROW_TOL * 100.0
                )),
                Some(_) => {}
                None => regressions.push(format!(
                    "{op}: gated {metric} missing (null) in fresh run"
                )),
            }
        }
    }
    if matched < MIN_MATCHED {
        regressions.push(format!(
            "only {matched} baseline row(s) matched the fresh run (need >= {MIN_MATCHED}) — \
             renamed bench ops require a baseline refresh"
        ));
    }
    println!("bench gate: {matched} row(s) compared against {}", baselines_label(&base));
    Ok(regressions)
}

fn baselines_label(b: &Bench) -> String {
    format!("baseline with {} row(s)", b.rows.len())
}

/// One gated row: `None` = null in the JSON = not gated.
#[derive(Debug, Default, Clone, PartialEq)]
struct Row {
    bytes_per_step: Option<f64>,
    calls_per_step: Option<f64>,
    steps_per_s: Option<f64>,
    ls_steps_per_s: Option<f64>,
    dist_steps_per_s: Option<f64>,
    update_wall_s: Option<f64>,
    seg_eval_wall_s: Option<f64>,
    collect_wall_s: Option<f64>,
    aip_update_wall_s: Option<f64>,
    serve_p50_us: Option<f64>,
    serve_p99_us: Option<f64>,
}

struct Bench {
    rows: BTreeMap<String, Row>,
    sim_zero_alloc: bool,
}

impl Bench {
    fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let obj = v.as_object().context("top level is not an object")?;
        let sim_zero_alloc = match obj.get("sim_zero_alloc") {
            Some(json::Value::Bool(b)) => *b,
            _ => bail!("missing boolean sim_zero_alloc"),
        };
        let rows_v = obj.get("rows").context("missing rows")?;
        let mut rows = BTreeMap::new();
        for r in rows_v.as_array().context("rows is not an array")? {
            let r = r.as_object().context("row is not an object")?;
            let op = match r.get("op") {
                Some(json::Value::Str(s)) => s.clone(),
                _ => bail!("row missing string op"),
            };
            rows.insert(
                op,
                Row {
                    bytes_per_step: num(r.get("bytes_per_step")),
                    calls_per_step: num(r.get("calls_per_step")),
                    steps_per_s: num(r.get("steps_per_s")),
                    ls_steps_per_s: num(r.get("ls_steps_per_s")),
                    dist_steps_per_s: num(r.get("dist_steps_per_s")),
                    update_wall_s: num(r.get("update_wall_s")),
                    seg_eval_wall_s: num(r.get("seg_eval_wall_s")),
                    collect_wall_s: num(r.get("collect_wall_s")),
                    aip_update_wall_s: num(r.get("aip_update_wall_s")),
                    serve_p50_us: num(r.get("serve_p50_us")),
                    serve_p99_us: num(r.get("serve_p99_us")),
                },
            );
        }
        Ok(Bench { rows, sim_zero_alloc })
    }
}

fn num(v: Option<&json::Value>) -> Option<f64> {
    match v {
        Some(json::Value::Num(x)) => Some(*x),
        _ => None,
    }
}

/// Minimal JSON reader (the offline vendor ships no serde): objects,
/// arrays, strings with escapes, numbers, booleans, null. Enough for the
/// bench documents this binary consumes — it rejects anything malformed.
mod json {
    use anyhow::{bail, Result};
    use std::collections::BTreeMap;

    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(BTreeMap<String, Value>),
    }

    impl Value {
        pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
            match self {
                Value::Obj(m) => Some(m),
                _ => None,
            }
        }

        pub fn as_array(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(v) => Some(v),
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
            *pos += 1;
        }
    }

    fn value(b: &[u8], pos: &mut usize) -> Result<Value> {
        skip_ws(b, pos);
        match b.get(*pos) {
            None => bail!("unexpected end of input"),
            Some(b'{') => object(b, pos),
            Some(b'[') => array(b, pos),
            Some(b'"') => Ok(Value::Str(string(b, pos)?)),
            Some(b't') => lit(b, pos, "true", Value::Bool(true)),
            Some(b'f') => lit(b, pos, "false", Value::Bool(false)),
            Some(b'n') => lit(b, pos, "null", Value::Null),
            Some(_) => number(b, pos),
        }
    }

    fn lit(b: &[u8], pos: &mut usize, word: &str, v: Value) -> Result<Value> {
        if b[*pos..].starts_with(word.as_bytes()) {
            *pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {pos}")
        }
    }

    fn number(b: &[u8], pos: &mut usize) -> Result<Value> {
        let start = *pos;
        while *pos < b.len()
            && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            *pos += 1;
        }
        let s = std::str::from_utf8(&b[start..*pos])?;
        match s.parse::<f64>() {
            Ok(x) => Ok(Value::Num(x)),
            Err(_) => bail!("bad number {s:?} at byte {start}"),
        }
    }

    fn string(b: &[u8], pos: &mut usize) -> Result<String> {
        if b.get(*pos) != Some(&b'"') {
            bail!("expected string at byte {pos}");
        }
        *pos += 1;
        let mut out = String::new();
        loop {
            match b.get(*pos) {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    *pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    *pos += 1;
                    match b.get(*pos) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = b
                                .get(*pos + 1..*pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            *pos += 4;
                        }
                        _ => bail!("bad escape at byte {pos}"),
                    }
                    *pos += 1;
                }
                Some(&c) => {
                    // Multi-byte UTF-8 sequences pass through untouched.
                    let ch_len = utf8_len(c);
                    let chunk = b
                        .get(*pos..*pos + ch_len)
                        .ok_or_else(|| anyhow::anyhow!("truncated utf-8"))?;
                    out.push_str(std::str::from_utf8(chunk)?);
                    *pos += ch_len;
                }
            }
        }
    }

    fn utf8_len(first: u8) -> usize {
        match first {
            0x00..=0x7f => 1,
            0xc0..=0xdf => 2,
            0xe0..=0xef => 3,
            _ => 4,
        }
    }

    fn array(b: &[u8], pos: &mut usize) -> Result<Value> {
        *pos += 1; // [
        let mut out = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b']') {
            *pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(value(b, pos)?);
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b']') => {
                    *pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => bail!("expected , or ] at byte {pos}"),
            }
        }
    }

    fn object(b: &[u8], pos: &mut usize) -> Result<Value> {
        *pos += 1; // {
        let mut out = BTreeMap::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b'}') {
            *pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            skip_ws(b, pos);
            let key = string(b, pos)?;
            skip_ws(b, pos);
            if b.get(*pos) != Some(&b':') {
                bail!("expected : at byte {pos}");
            }
            *pos += 1;
            out.insert(key, value(b, pos)?);
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b'}') => {
                    *pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => bail!("expected , or }} at byte {pos}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A bench document with every metric populated.
    fn doc(calls: f64, bytes: f64, sps: f64, zero_alloc: bool) -> String {
        doc_with_walls(calls, bytes, sps, zero_alloc, 0.5, 0.3)
    }

    fn doc_with_walls(
        calls: f64,
        bytes: f64,
        sps: f64,
        zero_alloc: bool,
        eval_wall: f64,
        collect_wall: f64,
    ) -> String {
        format!(
            "{{\n  \"bench\": \"hotpath\",\n  \"rows\": [\n\
             {{\"op\": \"traffic LS step\", \"mean_s\": 0.000001, \"min_s\": 0.000001, \"bytes_per_step\": 0.000, \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \"collect_wall_s\": null}},\n\
             {{\"op\": \"warehouse LS step\", \"mean_s\": 0.000001, \"min_s\": 0.000001, \"bytes_per_step\": 0.000, \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \"collect_wall_s\": null}},\n\
             {{\"op\": \"traffic GS step (25 ints)\", \"mean_s\": 0.00001, \"min_s\": 0.00001, \"bytes_per_step\": 0.000, \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": {sps}, \"seg_eval_wall_s\": null, \"collect_wall_s\": null}},\n\
             {{\"op\": \"warehouse GS step (25 rb)\", \"mean_s\": 0.00001, \"min_s\": 0.00001, \"bytes_per_step\": {bytes}, \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \"collect_wall_s\": null}},\n\
             {{\"op\": \"traffic GS eval joint step (batched, N=25)\", \"mean_s\": 0.0001, \"min_s\": 0.0001, \"bytes_per_step\": null, \"peak_extra_bytes\": 64, \"calls_per_step\": {calls}, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \"collect_wall_s\": null}},\n\
             {{\"op\": \"coordinator run, async eval x2 (16 agents)\", \"mean_s\": 0.5, \"min_s\": 0.4, \"bytes_per_step\": null, \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \"seg_eval_wall_s\": {eval_wall}, \"collect_wall_s\": null}},\n\
             {{\"op\": \"coordinator run, async collect (16 agents)\", \"mean_s\": 0.5, \"min_s\": 0.4, \"bytes_per_step\": null, \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \"collect_wall_s\": {collect_wall}}}\n\
             ],\n  \"sim_zero_alloc\": {zero_alloc}\n}}\n"
        )
    }

    /// `doc` plus one megabatch LS training row whose `ls_steps_per_s` is
    /// the given JSON literal (a number, or "null" for ungated).
    fn doc_with_ls(ls_sps: &str) -> String {
        doc(1.0, 0.0, 50_000.0, true).replace(
            "\n],",
            &format!(
                ",\n{{\"op\": \"traffic megabatch LS train x8 (N=4)\", \"mean_s\": 0.0001, \
                 \"min_s\": 0.0001, \"bytes_per_step\": null, \"peak_extra_bytes\": 0, \
                 \"calls_per_step\": 2.000, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \
                 \"collect_wall_s\": null, \"ls_steps_per_s\": {ls_sps}}}\n],"
            ),
        )
    }

    /// `doc` plus one fused-update megabatch row whose `update_wall_s` is
    /// the given JSON literal (a number, or "null" for ungated).
    fn doc_with_update(upd_wall: &str) -> String {
        doc(1.0, 0.0, 50_000.0, true).replace(
            "\n],",
            &format!(
                ",\n{{\"op\": \"traffic megabatch PPO update x512 (fused, N=4)\", \
                 \"mean_s\": 0.0001, \"min_s\": 0.0001, \"bytes_per_step\": null, \
                 \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \
                 \"seg_eval_wall_s\": null, \"collect_wall_s\": null, \
                 \"ls_steps_per_s\": 90000.0, \"update_wall_s\": {upd_wall}}}\n],"
            ),
        )
    }

    /// `doc` plus one fused AIP-retrain row whose `aip_update_wall_s` is
    /// the given JSON literal (a number, or "null" for ungated).
    fn doc_with_aip(aip_wall: &str) -> String {
        doc(1.0, 0.0, 50_000.0, true).replace(
            "\n],",
            &format!(
                ",\n{{\"op\": \"traffic AIP retrain x8 epochs (fused, N=16)\", \
                 \"mean_s\": 0.0001, \"min_s\": 0.0001, \"bytes_per_step\": null, \
                 \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \
                 \"seg_eval_wall_s\": null, \"collect_wall_s\": null, \
                 \"aip_update_wall_s\": {aip_wall}}}\n],"
            ),
        )
    }

    /// `doc` plus one multi-process `DistPlan` loopback row whose
    /// `dist_steps_per_s` is the given JSON literal (a number, or "null"
    /// for ungated).
    fn doc_with_dist(dist_sps: &str) -> String {
        doc(1.0, 0.0, 50_000.0, true).replace(
            "\n],",
            &format!(
                ",\n{{\"op\": \"traffic dist GS step x2 procs (N=576)\", \
                 \"mean_s\": 0.0001, \"min_s\": 0.0001, \"bytes_per_step\": null, \
                 \"peak_extra_bytes\": 0, \"calls_per_step\": null, \"steps_per_s\": null, \
                 \"seg_eval_wall_s\": null, \"collect_wall_s\": null, \
                 \"dist_steps_per_s\": {dist_sps}}}\n],"
            ),
        )
    }

    /// `doc` plus one `dials serve` load-gen row whose percentile columns
    /// are the given JSON literals (numbers, or "null" for ungated).
    fn doc_with_serve(p50: &str, p99: &str) -> String {
        doc(1.0, 0.0, 50_000.0, true).replace(
            "\n],",
            &format!(
                ",\n{{\"op\": \"serve e2e S=8 (N=1)\", \"mean_s\": 0.0001, \
                 \"min_s\": 0.0001, \"bytes_per_step\": null, \"peak_extra_bytes\": 0, \
                 \"calls_per_step\": null, \"steps_per_s\": null, \"seg_eval_wall_s\": null, \
                 \"collect_wall_s\": null, \"serve_p50_us\": {p50}, \"serve_p99_us\": {p99}}}\n],"
            ),
        )
    }

    #[test]
    fn identical_docs_pass() {
        let d = doc(1.0, 0.0, 50_000.0, true);
        assert!(diff(&d, &d).unwrap().is_empty());
    }

    #[test]
    fn calls_per_step_regression_fails() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        let fresh = doc(25.0, 0.0, 50_000.0, true);
        let regs = diff(&fresh, &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("calls_per_step"), "{regs:?}");
    }

    #[test]
    fn bytes_per_step_regression_fails() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        let fresh = doc(1.0, 64.0, 50_000.0, true);
        let regs = diff(&fresh, &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("bytes_per_step"), "{regs:?}");
    }

    #[test]
    fn steps_per_s_gets_20_percent_tolerance() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        // 10% slower: inside tolerance
        assert!(diff(&doc(1.0, 0.0, 45_000.0, true), &base).unwrap().is_empty());
        // 25% slower: regression
        let regs = diff(&doc(1.0, 0.0, 37_000.0, true), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("steps_per_s"), "{regs:?}");
    }

    #[test]
    fn ls_steps_per_s_gets_20_percent_tolerance() {
        let base = doc_with_ls("40000.0");
        // 12.5% slower: inside tolerance
        assert!(diff(&doc_with_ls("35000.0"), &base).unwrap().is_empty());
        // improvement: always passes
        assert!(diff(&doc_with_ls("90000.0"), &base).unwrap().is_empty());
        // 25% slower: regression
        let regs = diff(&doc_with_ls("30000.0"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("ls_steps_per_s"), "{regs:?}");
    }

    #[test]
    fn null_baseline_ls_steps_per_s_is_not_gated() {
        let base = doc_with_ls("null");
        // fresh value present but baseline never recorded one: ungated
        assert!(diff(&doc_with_ls("1.0"), &base).unwrap().is_empty());
    }

    #[test]
    fn gated_ls_steps_per_s_going_null_in_fresh_run_fails() {
        let base = doc_with_ls("40000.0");
        let regs = diff(&doc_with_ls("null"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("ls_steps_per_s"), "{regs:?}");
        assert!(regs[0].contains("missing"), "{regs:?}");
    }

    #[test]
    fn dist_steps_per_s_gets_20_percent_tolerance() {
        let base = doc_with_dist("10000.0");
        // 12.5% slower: inside tolerance
        assert!(diff(&doc_with_dist("8750.0"), &base).unwrap().is_empty());
        // improvement: always passes
        assert!(diff(&doc_with_dist("30000.0"), &base).unwrap().is_empty());
        // 25% slower: regression
        let regs = diff(&doc_with_dist("7500.0"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("dist_steps_per_s"), "{regs:?}");
    }

    #[test]
    fn null_baseline_dist_steps_per_s_is_not_gated() {
        let base = doc_with_dist("null");
        // fresh value present but the baseline never recorded one: ungated
        assert!(diff(&doc_with_dist("1.0"), &base).unwrap().is_empty());
    }

    #[test]
    fn gated_dist_steps_per_s_going_null_in_fresh_run_fails() {
        let base = doc_with_dist("10000.0");
        let regs = diff(&doc_with_dist("null"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("dist_steps_per_s"), "{regs:?}");
        assert!(regs[0].contains("missing"), "{regs:?}");
    }

    #[test]
    fn update_wall_gets_25_percent_growth_tolerance() {
        let base = doc_with_update("0.40");
        // +20%: inside tolerance
        assert!(diff(&doc_with_update("0.48"), &base).unwrap().is_empty());
        // improvement: always passes
        assert!(diff(&doc_with_update("0.10"), &base).unwrap().is_empty());
        // +50%: regression
        let regs = diff(&doc_with_update("0.60"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("update_wall_s"), "{regs:?}");
    }

    #[test]
    fn null_baseline_update_wall_is_not_gated() {
        let base = doc_with_update("null");
        // fresh value present but the baseline never recorded one
        assert!(diff(&doc_with_update("99.0"), &base).unwrap().is_empty());
    }

    #[test]
    fn gated_update_wall_going_null_in_fresh_run_fails() {
        let base = doc_with_update("0.40");
        let regs = diff(&doc_with_update("null"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("update_wall_s"), "{regs:?}");
        assert!(regs[0].contains("missing"), "{regs:?}");
    }

    #[test]
    fn aip_update_wall_gets_25_percent_growth_tolerance() {
        let base = doc_with_aip("0.40");
        // +20%: inside tolerance
        assert!(diff(&doc_with_aip("0.48"), &base).unwrap().is_empty());
        // improvement: always passes
        assert!(diff(&doc_with_aip("0.10"), &base).unwrap().is_empty());
        // +50%: regression
        let regs = diff(&doc_with_aip("0.60"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("aip_update_wall_s"), "{regs:?}");
    }

    #[test]
    fn null_baseline_aip_update_wall_is_not_gated() {
        let base = doc_with_aip("null");
        // fresh value present but the baseline never recorded one
        assert!(diff(&doc_with_aip("99.0"), &base).unwrap().is_empty());
    }

    #[test]
    fn gated_aip_update_wall_going_null_in_fresh_run_fails() {
        let base = doc_with_aip("0.40");
        let regs = diff(&doc_with_aip("null"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("aip_update_wall_s"), "{regs:?}");
        assert!(regs[0].contains("missing"), "{regs:?}");
    }

    #[test]
    fn serve_percentiles_get_25_percent_growth_tolerance() {
        let base = doc_with_serve("120.0", "400.0");
        // +20% on both: inside tolerance
        assert!(diff(&doc_with_serve("144.0", "480.0"), &base).unwrap().is_empty());
        // improvement: always passes
        assert!(diff(&doc_with_serve("60.0", "200.0"), &base).unwrap().is_empty());
        // +50% p50: regression
        let regs = diff(&doc_with_serve("180.0", "400.0"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("serve_p50_us"), "{regs:?}");
        // +50% p99: regression
        let regs = diff(&doc_with_serve("120.0", "600.0"), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("serve_p99_us"), "{regs:?}");
    }

    #[test]
    fn null_baseline_serve_percentiles_are_not_gated() {
        let base = doc_with_serve("null", "null");
        // fresh percentiles present but the baseline never recorded any
        assert!(diff(&doc_with_serve("9999.0", "9999.0"), &base).unwrap().is_empty());
    }

    #[test]
    fn gated_serve_percentile_going_null_in_fresh_run_fails() {
        let base = doc_with_serve("120.0", "400.0");
        let regs = diff(&doc_with_serve("null", "null"), &base).unwrap();
        assert_eq!(regs.len(), 2, "{regs:?}");
        assert!(regs[0].contains("serve_p50_us") && regs[0].contains("missing"), "{regs:?}");
        assert!(regs[1].contains("serve_p99_us") && regs[1].contains("missing"), "{regs:?}");
    }

    #[test]
    fn improvements_pass() {
        let base = doc(25.0, 64.0, 50_000.0, true);
        assert!(diff(&doc(1.0, 0.0, 90_000.0, true), &base).unwrap().is_empty());
    }

    #[test]
    fn overlap_wall_growth_beyond_tolerance_fails() {
        let base = doc_with_walls(1.0, 0.0, 50_000.0, true, 0.5, 0.3);
        // +20% on both walls: inside the 25% tolerance
        let ok = doc_with_walls(1.0, 0.0, 50_000.0, true, 0.6, 0.36);
        assert!(diff(&ok, &base).unwrap().is_empty());
        // +50% seg_eval wall: regression
        let regs =
            diff(&doc_with_walls(1.0, 0.0, 50_000.0, true, 0.75, 0.3), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("seg_eval_wall_s"), "{regs:?}");
        // +50% collect wall: regression
        let regs =
            diff(&doc_with_walls(1.0, 0.0, 50_000.0, true, 0.5, 0.45), &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("collect_wall_s"), "{regs:?}");
        // improvements always pass
        assert!(
            diff(&doc_with_walls(1.0, 0.0, 50_000.0, true, 0.2, 0.1), &base).unwrap().is_empty()
        );
    }

    #[test]
    fn null_baseline_walls_are_not_gated() {
        let base = doc_with_walls(1.0, 0.0, 50_000.0, true, 0.5, 0.3)
            .replace("\"collect_wall_s\": 0.3", "\"collect_wall_s\": null");
        // fresh collect wall is 10x worse but the baseline says ungated
        assert!(
            diff(&doc_with_walls(1.0, 0.0, 50_000.0, true, 0.5, 3.0), &base).unwrap().is_empty()
        );
    }

    #[test]
    fn gated_wall_going_null_in_fresh_run_fails() {
        let base = doc_with_walls(1.0, 0.0, 50_000.0, true, 0.5, 0.3);
        let fresh = doc_with_walls(1.0, 0.0, 50_000.0, true, 0.5, 0.3)
            .replace("\"collect_wall_s\": 0.3", "\"collect_wall_s\": null");
        let regs = diff(&fresh, &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("collect_wall_s") && regs[0].contains("missing"), "{regs:?}");
    }

    #[test]
    fn zero_alloc_gate_must_hold() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        let regs = diff(&doc(1.0, 0.0, 50_000.0, false), &base).unwrap();
        assert!(regs.iter().any(|r| r.contains("sim_zero_alloc")), "{regs:?}");
    }

    #[test]
    fn gated_metric_going_null_in_fresh_run_fails() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        // the fresh run stops reporting the gated steps_per_s → fail closed
        let fresh = doc(1.0, 0.0, 50_000.0, true)
            .replace("\"steps_per_s\": 50000", "\"steps_per_s\": null");
        let regs = diff(&fresh, &base).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("missing (null)"), "{regs:?}");
    }

    #[test]
    fn null_baseline_metrics_are_not_gated() {
        let base = doc(1.0, 0.0, 50_000.0, true)
            .replace("\"steps_per_s\": 50000", "\"steps_per_s\": null");
        // fresh is 90% slower on that row but the baseline says "ungated"
        assert!(diff(&doc(1.0, 0.0, 5_000.0, true), &base).unwrap().is_empty());
    }

    #[test]
    fn missing_fresh_row_warns_but_does_not_fail() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        // drop one baseline-matched row from the fresh doc (still >= MIN_MATCHED)
        let fresh = base.replace("traffic GS eval joint step (batched, N=25)", "renamed op");
        assert!(diff(&fresh, &base).unwrap().is_empty());
    }

    #[test]
    fn too_few_matched_rows_fails() {
        let base = doc(1.0, 0.0, 50_000.0, true);
        let fresh = doc(1.0, 0.0, 50_000.0, true).replace("\"op\": \"", "\"op\": \"renamed ");
        let regs = diff(&fresh, &base).unwrap();
        assert!(regs.iter().any(|r| r.contains("baseline row")), "{regs:?}");
    }

    #[test]
    fn real_generator_format_parses() {
        // Mirrors write_json in benches/hotpath.rs, including nulls & NaN-free floats.
        let text = "{\n  \"bench\": \"hotpath\",\n  \"rows\": [\n    {\"op\": \"x\", \
                    \"mean_s\": 0.000001234, \"min_s\": 0.000001000, \"bytes_per_step\": null, \
                    \"peak_extra_bytes\": 128, \"calls_per_step\": 1.000, \"steps_per_s\": 123.4, \
                    \"ls_steps_per_s\": 4096.5, \"seg_eval_wall_s\": null}\n  ],\n  \
                    \"sim_zero_alloc\": true\n}\n";
        let b = Bench::parse(text).unwrap();
        assert_eq!(b.rows.len(), 1);
        assert!(b.sim_zero_alloc);
        let row = &b.rows["x"];
        assert_eq!(row.calls_per_step, Some(1.0));
        assert_eq!(row.bytes_per_step, None);
        assert_eq!(row.steps_per_s, Some(123.4));
        assert_eq!(row.ls_steps_per_s, Some(4096.5));
    }

    #[test]
    fn old_schema_without_seg_eval_wall_parses() {
        let text = "{\"bench\": \"hotpath\", \"rows\": [{\"op\": \"y\", \"mean_s\": 1.0, \
                    \"min_s\": 1.0, \"bytes_per_step\": 0.0, \"peak_extra_bytes\": 0, \
                    \"calls_per_step\": null, \"steps_per_s\": null}], \"sim_zero_alloc\": true}";
        assert!(Bench::parse(text).is_ok());
    }
}
