//! Warehouse commissioning fleet (paper §5.2): GRU policies + GRU AIPs.
//!
//! Demonstrates the paper's §4.3 finding in miniature: in this weakly
//! coupled domain, training the AIPs ONCE at the start (F = total) is as
//! good as retraining them frequently — and strictly cheaper.
//!
//!     cargo run --release --offline --example warehouse_fleet -- --steps 3000

use anyhow::Result;

use dials::baselines::scripted_return;
use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::{fmt_secs, Table};
use dials::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 3000)?;
    let side = args.get_usize("grid-side", 2)?;
    let seed = args.get_u64("seed", 0)?;

    let engine = Engine::cpu()?;
    let mut table = Table::new(
        &format!("warehouse fleet: {} robots, {} steps/agent", side * side, steps),
        &["condition", "final return", "total (CP)"],
    );

    // Condition sweep: retrain-often vs train-once vs never (untrained).
    let conditions: Vec<(String, SimMode, usize)> = vec![
        (format!("DIALS F={}", steps / 4), SimMode::Dials, steps / 4),
        (format!("DIALS F={steps} (once)"), SimMode::Dials, steps),
        ("untrained-DIALS".into(), SimMode::UntrainedDials, steps),
    ];

    for (label, mode, f) in conditions {
        let cfg = ExperimentConfig {
            domain: Domain::Warehouse,
            mode,
            grid_side: side,
            total_steps: steps,
            aip_train_freq: f.max(1),
            aip_dataset: 600,
            aip_epochs: 40,
            eval_every: steps / 4,
            eval_episodes: 2,
            horizon: 100,
            seed,
            ..Default::default()
        };
        let coord = DialsCoordinator::new(&engine, cfg)?;
        let log = coord.run()?;
        println!("[{label}] curve:");
        for p in &log.eval_curve {
            println!("  step {:>6}  return {:>8.3}", p.step, p.value);
        }
        table.row(vec![
            label,
            format!("{:.3}", log.final_return),
            fmt_secs(log.critical_path_seconds),
        ]);
    }

    let scripted = scripted_return(Domain::Warehouse, side, 4, 100, seed);
    table.row(vec!["hand-coded (greedy oldest)".into(), format!("{scripted:.3}"), "-".into()]);
    table.print();
    table.save_csv("warehouse_fleet");
    Ok(())
}
