//! A city-scale traffic scenario: 25 signalised intersections trained with
//! DIALS (paper §5.2 traffic, Fig. 4a environment).
//!
//! Shows the knobs a practitioner would touch: the AIP retrain frequency
//! `F`, the dataset size, and the thread pool — and prints the runtime
//! breakdown in the shape of the paper's Table 1.
//!
//!     cargo run --release --offline --example traffic_city -- --steps 2000

use anyhow::Result;

use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::{fmt_secs, Table};
use dials::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 2000)?;
    let side = args.get_usize("grid-side", 5)?;

    let engine = Engine::cpu()?;
    let mut table = Table::new(
        &format!("traffic city: {} intersections, {} steps/agent", side * side, steps),
        &["F", "final return", "agents train (CP)", "data+AIP", "total (CP)"],
    );

    // Sweep the AIP training frequency like the paper's Fig. 4a.
    for divisor in [4usize, 2, 1] {
        let f = (steps / divisor).max(1);
        let cfg = ExperimentConfig {
            domain: Domain::Traffic,
            mode: SimMode::Dials,
            grid_side: side,
            total_steps: steps,
            aip_train_freq: f,
            aip_dataset: 400,
            aip_epochs: 25,
            eval_every: steps / 2,
            eval_episodes: 2,
            horizon: 100,
            seed: 0,
            ..Default::default()
        };
        let coord = DialsCoordinator::new(&engine, cfg)?;
        let log = coord.run()?;
        table.row(vec![
            format!("{f}"),
            format!("{:.3}", log.final_return),
            fmt_secs(log.agent_train_seconds),
            fmt_secs(log.influence_seconds),
            fmt_secs(log.critical_path_seconds),
        ]);
        println!(
            "[F={f}] CE curve: {}",
            log.ce_curve.iter().map(|p| format!("{:.3}", p.value)).collect::<Vec<_>>().join(" -> ")
        );
    }
    table.print();
    table.save_csv("traffic_city");
    println!("\nNote: 'CP' = critical path, the wall-clock a >=N-core machine measures.");
    Ok(())
}
