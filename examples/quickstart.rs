//! Quickstart — the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Trains the 4-intersection traffic scenario with all three simulator
//! conditions from the paper (GS, DIALS, untrained-DIALS), on the REAL
//! stack: rust coordinator → PJRT-compiled jax/pallas networks → rust
//! cellular-automaton simulators. Prints the learning curves, the
//! hand-coded baseline, and the runtime breakdown. ~1-2 minutes on 1 CPU.
//!
//!     cargo run --release --offline --example quickstart
//!     cargo run --release --offline --example quickstart -- --steps 8000

use anyhow::Result;

use dials::baselines::{scripted_return, GsTrainer};
use dials::config::{Domain, ExperimentConfig, SimMode};
use dials::coordinator::DialsCoordinator;
use dials::runtime::Engine;
use dials::util::bench::{fmt_secs, Table};
use dials::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 4000)?;
    let seed = args.get_u64("seed", 0)?;

    let base = ExperimentConfig {
        domain: Domain::Traffic,
        grid_side: 2,
        total_steps: steps,
        aip_train_freq: steps / 4,
        aip_dataset: 800,
        aip_epochs: 40,
        eval_every: steps / 8,
        eval_episodes: 3,
        horizon: 100,
        seed,
        ..Default::default()
    };

    let engine = Engine::cpu()?;
    println!("platform: {}", engine.platform());
    println!("domain  : traffic, {} agents, {} steps/agent\n", base.n_agents(), steps);

    let mut curves = Vec::new();
    let mut table = Table::new(
        "quickstart: 4-intersection traffic (paper Fig. 3a, scaled)",
        &["condition", "final return", "wall", "critical path"],
    );

    for mode in [SimMode::GlobalSim, SimMode::Dials, SimMode::UntrainedDials] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        let coord = DialsCoordinator::new(&engine, cfg)?;
        let log = match mode {
            SimMode::GlobalSim => GsTrainer::new(coord).run()?,
            _ => coord.run()?,
        };
        println!("[{}] curve:", log.label);
        for p in &log.eval_curve {
            println!("  step {:>6}  return {:>8.3}", p.step, p.value);
        }
        table.row(vec![
            log.label.clone(),
            format!("{:.3}", log.final_return),
            fmt_secs(log.wall_seconds),
            fmt_secs(log.critical_path_seconds),
        ]);
        curves.push(log);
    }

    let scripted = scripted_return(Domain::Traffic, 2, 5, base.horizon, seed);
    table.row(vec!["hand-coded (fixed cycle)".into(), format!("{scripted:.3}"), "-".into(), "-".into()]);
    table.print();
    table.save_csv("quickstart");

    println!("\nPaper-shape checks:");
    let dials = &curves[1];
    let untrained = &curves[2];
    println!(
      "  DIALS ({:.2}) vs untrained-DIALS ({:.2}): {}",
      dials.final_return, untrained.final_return,
      if dials.final_return >= untrained.final_return { "OK (influence estimation matters)" } else { "NOT reproduced at this budget" }
    );
    Ok(())
}
